"""Paper Fig 3 — transfer time of various worker counts while increasing
the prefetch factor (CIFAR-10).

The claim: curves are roughly flat in prefetch (workers dominate) but not
monotone — the optimum prefetch is unpredictable and must be searched.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import (LoaderSimulator, MachineProfile, SimulatorEvaluator)
from repro.data.storage import cifar10_profile

TITLE = "Prefetch sweep at fixed worker counts"
PAPER_REF = "Fig 3"

MACHINE = MachineProfile()
BATCH = 32
WORKERS = (2, 4, 6, 8, 10, 12)


def run(quick: bool = False) -> List[Dict]:
    sim = LoaderSimulator(cifar10_profile(), MACHINE)
    ev = SimulatorEvaluator(sim, batch_size=BATCH)
    nb = 32 if quick else 64
    rows: List[Dict] = []
    for w in WORKERS:
        ts = {j: ev(w, j, num_batches=nb, epoch=1).seconds
              for j in range(1, 9)}
        best_j = min(ts, key=ts.get)
        rows.append({
            "worker": w, "best_prefetch": best_j, "best_s": ts[best_j],
            "prefetch1_s": ts[1], "prefetch8_s": ts[8],
            "flatness_pct": 100 * (max(ts.values()) - min(ts.values()))
                            / min(ts.values()),
        })
    # cross-worker contrast: worker gains dwarf prefetch gains
    t_w2 = min(rows[0][k] for k in ("best_s",))
    t_w10 = [r for r in rows if r["worker"] == 10][0]["best_s"]
    rows.append({"worker": "2->10", "best_prefetch": "-",
                 "best_s": t_w10, "prefetch1_s": t_w2,
                 "prefetch8_s": None,
                 "flatness_pct": 100 * (t_w2 / t_w10 - 1)})
    return rows


def main() -> None:
    from benchmarks.common import fmt_table, save_rows
    rows = run()
    print(f"== {TITLE} ({PAPER_REF}) ==")
    print(fmt_table(rows))
    print(save_rows("prefetch", rows))


if __name__ == "__main__":
    main()
