"""Beyond-paper: multi-host fleet tuning (DESIGN.md §2 multi-pod semantics).

A lockstep SPMD fleet's effective transfer time is the MAX over hosts, so
per-host tuning and straggler-aware uniform consensus beat both (a) the
framework default and (b) naively applying the fast-host optimum fleet-wide.
Scenario: 16 hosts, 2 degraded (half cores / 0.3x storage bw) — the
straggler-injection case the single-machine paper cannot express.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import (DPTConfig, LoaderSimulator, MachineProfile,
                        MultiHostDPT, SimulatorEvaluator, default_params)
from repro.core.cluster import fleet_evaluators, make_fleet
from repro.data.storage import coco_profile

TITLE = "Fleet tuning under stragglers (per-host vs uniform vs default)"
PAPER_REF = "beyond-paper (DESIGN.md §2)"

BATCH = 64


def run(quick: bool = False) -> List[Dict]:
    machine = MachineProfile()
    storage = coco_profile(160)
    num_hosts = 4 if quick else 16
    fleet = make_fleet(machine, storage, num_hosts=num_hosts,
                       slow_hosts=(1, 3) if num_hosts >= 4 else (1,))
    evs = fleet_evaluators(fleet, batch_size=BATCH)
    cfg = DPTConfig(num_cpu_cores=12, num_devices=1,
                    max_prefetch=4, num_batches=16 if quick else 32, epoch=1)
    tuner = MultiHostDPT(evs, cfg)

    per_host = tuner.run_per_host()
    uniform = tuner.run_uniform()

    # fleet default: every host runs PyTorch defaults
    dw, dp = default_params(12)
    t_default = max(ev(dw, dp, num_batches=cfg.num_batches,
                       epoch=cfg.epoch).seconds for ev in evs)
    # naive: fast-host optimum applied fleet-wide
    fast = per_host.per_host[0]
    t_naive = max(ev(fast.nworker, fast.nprefetch,
                     num_batches=cfg.num_batches, epoch=cfg.epoch).seconds
                  for ev in evs)

    rows: List[Dict] = [
        {"policy": "framework-default", "fleet_s": t_default,
         "params": f"({dw},{dp}) everywhere",
         "speedup_vs_default": 1.0},
        {"policy": "fast-host-everywhere", "fleet_s": t_naive,
         "params": f"({fast.nworker},{fast.nprefetch}) everywhere",
         "speedup_vs_default": t_default / t_naive},
        {"policy": "uniform-minimax", "fleet_s": uniform.fleet_time,
         "params": f"{uniform.uniform_params} everywhere",
         "speedup_vs_default": t_default / uniform.fleet_time},
        {"policy": "per-host", "fleet_s": per_host.fleet_time,
         "params": "per-host optima",
         "speedup_vs_default": t_default / per_host.fleet_time},
    ]
    # show the straggler's own optimum vs a healthy host's
    slow = per_host.per_host[1]
    rows.append({"policy": "(host1=straggler optimum)",
                 "fleet_s": slow.optimal_time,
                 "params": f"({slow.nworker},{slow.nprefetch})",
                 "speedup_vs_default": None})
    return rows


def main() -> None:
    from benchmarks.common import fmt_table, save_rows
    rows = run()
    print(f"== {TITLE} ({PAPER_REF}) ==")
    print(fmt_table(rows))
    print(save_rows("multihost", rows))


if __name__ == "__main__":
    main()
