"""Elastic fleet gate: degrade + kill a host mid-run, recover goodput.

Runs REAL machinery (thread worker pools over sleep-based LatencyStorage,
live hot-swappable streams, the fleet control plane) through a scheduled
failure scenario:

  phase 1  three hosts, coordinator-tuned uniform params, lockstep rounds;
  phase 2  host1's storage degrades 25x mid-run — the straggler/stall
           signal drives a uniform re-consensus (the transition window's
           rate includes the retune cost: that cost is real);
  phase 3  host2 goes silent — the heartbeat timeout declares it dead, the
           coordinator reshards the survivors at a common barrier (the
           dead host's undelivered slices redistributed as makeup) and
           follows with a re-consensus for the 2-host topology;
  phase 4  the surviving fleet runs the epoch out.

Two gates, both recorded in ``BENCH_fleet.json`` at the repo root (CI
uploads it as a workflow artifact):

* **recovery** — post-failure fleet goodput must reach >= 80% of the
  pre-failure N-1-host optimum (a separately tuned fleet of the two
  surviving host profiles — host0 healthy, host1 degraded — measured with
  the same lockstep driver).  The hard-fail threshold is overridable via
  ``FLEET_GATE_MIN`` for noisy shared CI runners; the honest 0.8 gate is
  what the JSON records.
* **coverage** — every dataset index is delivered exactly once for the
  epoch spanning the elastic transition: the dead host's pre-death
  deliveries + survivors' old-shard batches + makeup + new-shard batches.
  Asserted over the full index multiset, not sampled.

Two further scenarios ride the transport-mode control plane (ISSUE 7,
DESIGN.md §8), gated under ``FLEET_HA_GATE_MIN``:

* **failover** — the same real-machinery fleet attached over a faulty
  message transport, with a lease-backed standby.  The leader crashes
  mid-epoch; during an outage of 2x the heartbeat timeout the hosts keep
  streaming on latched params (goodput gate: >= 90% of steady state), the
  standby promotes with a fresh fencing epoch, every post-failover
  command carries the new fence, the deposed leader's commands are
  rejected, and the epoch still covers exactly once.
* **128-host stress** — a FleetSchedule run at 128 transport-attached
  hosts (degrade events + a 64-host correlated power loss) completes its
  reshard, while steady-state heartbeat traffic stays O(hosts): one
  report per host per round, delta-encoded smaller than the full report
  after the first beat.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.cluster import FleetEvent, FleetSchedule
from repro.core.dpt import DPTConfig, MultiHostDPT
from repro.core.evaluators import LoaderEvaluator
from repro.data import DataLoader, Dataset, LoaderParams
from repro.data.storage import ArrayStorage, LatencyStorage
from repro.tuning import (FaultSpec, FaultyTransport, FleetConfig,
                          FleetCoordinator, HostAgent, LeaderLease,
                          LinkConfig, LocalTransport, SnapshotStore,
                          StaleLeaderError, connect_host)
from repro.tuning.fleet import CoordinatorReplica, CoordinatorServer

TITLE = "Elastic fleet: degrade/kill + coordinator failover (HA gates)"
PAPER_REF = "beyond paper (fleet control plane, DESIGN.md §4, §8)"
GATE_RECOVERY = 0.80
GATE_FAILOVER = 0.90                # outage goodput vs steady state
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json")

GLOBAL_BATCH = 12
BASE_LATENCY_S = 1.5e-3
DEGRADE_SCALE = 25.0                # host1's storage latency multiplier
COMPUTE_S = 10e-3                   # synthetic lockstep model step
HEARTBEAT_TIMEOUT = 3.0             # in driver-clock rounds


def _make_host(n_items: int, host: int, host_count: int,
               latency_s: float) -> DataLoader:
    """An index-carrying dataset behind sleep-based storage: thread workers
    see true concurrency, and every delivered sample is accountable."""
    items = [np.full((4,), i, np.int32) for i in range(n_items)]
    storage = LatencyStorage(ArrayStorage(items), latency_s=latency_s,
                             bandwidth=1e9)
    ds = Dataset(storage, transform=lambda a: {"x": a})
    dl = DataLoader(ds, GLOBAL_BATCH, shuffle=True, seed=11,
                    params=LoaderParams(num_workers=2, prefetch_factor=2),
                    host_index=host, host_count=host_count)
    dl._bench_storage = storage     # the degrade event mutates latency_s
    return dl


def _search_cfg(quick: bool) -> Dict:
    return dict(num_cpu_cores=4, num_devices=1, max_prefetch=2,
                retune_budget_batches=5 if quick else 8)


def _rounds(streams: List, agents: Optional[List], rounds: int, *,
            sink: Optional[Dict[str, List]] = None,
            clock: Optional[List[float]] = None,
            coord: Optional[FleetCoordinator] = None) -> float:
    """Drive ``rounds`` lockstep global batches; returns global batches/s.

    Each round pulls one local batch per host (recording delivered indices
    into ``sink``), feeds the agents' goodput monitors, sleeps the
    synthetic compute and advances the fleet clock.  ``coord=None`` skips
    the decide step — measurement windows are poll-free so a re-consensus
    never lands inside the rate being gated (transition windows pass the
    coordinator and pay retune cost where it belongs)."""
    t0 = time.perf_counter()
    for _ in range(rounds):
        if clock is not None:
            clock[0] += 1.0
        for i, stream in enumerate(streams):
            t1 = time.perf_counter()
            batch = next(stream)
            data_s = time.perf_counter() - t1
            if sink is not None:
                sink[stream._bench_host].append(
                    np.asarray(batch["x"])[:, 0].copy())
            if agents is not None:
                agents[i].observe(data_s=data_s, step_s=data_s + COMPUTE_S)
        time.sleep(COMPUTE_S)
        if coord is not None:
            coord.poll()
    return rounds / (time.perf_counter() - t0)


def _reference_rate(n_items: int, quick: bool, window: int) -> Dict:
    """The pre-failure N-1-host optimum: a fresh fleet of the two SURVIVOR
    profiles (host0 healthy, host1 degraded), consensus-tuned, measured
    with the same lockstep driver."""
    latencies = [BASE_LATENCY_S, BASE_LATENCY_S * DEGRADE_SCALE]
    loaders = [_make_host(n_items, h, 2, lat)
               for h, lat in enumerate(latencies)]
    scfg = _search_cfg(quick)
    dpt_cfg = DPTConfig(num_cpu_cores=scfg["num_cpu_cores"],
                        num_devices=scfg["num_devices"],
                        max_prefetch=scfg["max_prefetch"],
                        num_batches=scfg["retune_budget_batches"])
    fleet = MultiHostDPT(
        [LoaderEvaluator(dl, to_device=False) for dl in loaders],
        dpt_cfg).run_uniform()
    for dl in loaders:
        dl.with_params(dl.params.replace(
            num_workers=fleet.uniform_params[0],
            prefetch_factor=fleet.uniform_params[1]))
    streams = []
    for h, dl in enumerate(loaders):
        s = dl.stream(to_device=False)
        s._bench_host = f"ref{h}"
        streams.append(s)
    _rounds(streams, None, max(4, window // 3))          # warm the pipeline
    rate = _rounds(streams, None, window)
    for s in streams:
        s.close()
    return {"rate": rate, "params": fleet.uniform_params}


def _ha_failover(quick: bool) -> Dict:
    """Leader crash mid-epoch over a faulty transport: hosts must keep
    streaming through an outage of 2x the heartbeat timeout, the standby
    must promote with a fresh fence, and the epoch must still cover
    exactly once.  Returns the measured facts; the caller gates them."""
    n_items = 720 if quick else 1440
    bpe = n_items // GLOBAL_BATCH
    warm = 6 if quick else 10
    window = 12 if quick else 24
    outage = int(2 * HEARTBEAT_TIMEOUT)

    clock = [0.0]
    ck = lambda: clock[0]  # noqa: E731
    transport = FaultyTransport(FaultSpec(drop=0.02, delay=0.01,
                                          duplicate=0.02, reply_drop=0.02,
                                          seed=3))
    lease = LeaderLease(ttl_s=HEARTBEAT_TIMEOUT, clock=ck)
    store = SnapshotStore()
    coord = FleetCoordinator(
        config=FleetConfig(heartbeat_timeout_s=HEARTBEAT_TIMEOUT,
                           cooldown_steps=8, warmup_steps=4,
                           **_search_cfg(quick)),
        clock=ck)
    server = [CoordinatorServer(coord, transport, owner="coord-0",
                                lease=lease, store=store)]
    replica = CoordinatorReplica(transport, lease, store,
                                 owner="coord-standby", clock=ck)

    loaders = [_make_host(n_items, h, 3, BASE_LATENCY_S) for h in range(3)]
    agents, streams = [], []
    for h, dl in enumerate(loaders):
        agents.append(connect_host(
            transport, f"host{h}", dl,
            evaluator=LoaderEvaluator(dl, to_device=False),
            clock=ck, link_config=LinkConfig(seed=h, jitter=0.0)))
        s = dl.stream(to_device=False)
        s._bench_host = f"host{h}"
        streams.append(s)
    delivered: Dict[str, List[np.ndarray]] = {
        f"host{h}": [] for h in range(3)}

    def rounds(k: int, *, poll: bool) -> float:
        """Lockstep rounds; returns HOST-side global batches/s.  Only the
        hosts' section of each round is timed (pulls, observes — which is
        where the link's failed-send/backoff path runs during an outage —
        and the synthetic compute): the coordinator/standby work this
        single-threaded driver interleaves runs on other machines in a
        real deployment and must not be billed to fleet goodput."""
        host_s = 0.0
        for _ in range(k):
            clock[0] += 1.0
            t0 = time.perf_counter()
            for i, stream in enumerate(streams):
                t1 = time.perf_counter()
                batch = next(stream)
                data_s = time.perf_counter() - t1
                delivered[stream._bench_host].append(
                    np.asarray(batch["x"])[:, 0].copy())
                agents[i].observe(data_s=data_s, step_s=data_s + COMPUTE_S)
            time.sleep(COMPUTE_S)
            host_s += time.perf_counter() - t0
            transport.pump()
            server[0].tick()
            if poll:
                server[0].poll()
            promoted = replica.tick()
            if promoted is not None:
                server[0] = promoted
        return k / host_s

    coord.request_consensus(reason="startup")
    server[0].poll()
    rounds(warm, poll=True)
    rate_steady = rounds(window, poll=False)

    old_server = server[0]
    old_fence = old_server.fence
    old_server.crash()
    # the outage window: no leader for ttl rounds, then the standby
    # promotes mid-window and catches the fleet up — all of that cost
    # lands inside the gated rate
    rate_outage = rounds(outage, poll=True)
    assert replica.promoted, "standby never promoted during the outage"
    rounds(3, poll=True)                   # links re-sync, catch-up pushes

    new_fence = server[0].fence
    fence_fresh = (new_fence > old_fence and not server[0].deposed
                   and all(a.link.fence == new_fence for a in agents))
    try:
        old_server.send("host0", "ping", {})
        stale_rejected = False
    except StaleLeaderError:
        stale_rejected = True

    rate_after = rounds(window, poll=False)
    for stream in streams:
        while stream.position < bpe:
            batch = next(stream)
            delivered[stream._bench_host].append(
                np.asarray(batch["x"])[:, 0].copy())
        stream.close()
    counts = np.bincount(
        np.concatenate([np.concatenate(c) for c in delivered.values() if c]),
        minlength=n_items)
    return {
        "rate_steady": rate_steady, "rate_outage": rate_outage,
        "rate_after": rate_after,
        "failover_goodput": rate_outage / rate_steady,
        "fence_fresh": bool(fence_fresh), "stale_rejected": stale_rejected,
        "coverage_exact": bool((counts == 1).all()),
        "lost": int((counts == 0).sum()), "dup": int((counts > 1).sum()),
        "n_items": n_items, "outage_rounds": outage,
        "old_fence": old_fence, "new_fence": new_fence,
    }


def _stress_128(quick: bool) -> Dict:
    """128 transport-attached hosts through a FleetSchedule (degrades +
    a 64-host correlated power loss).  The hosts carry real DataLoaders
    but never open streams — the stress is the control plane: steady
    heartbeat traffic must stay one report per host per round with the
    delta encoding smaller than the full report, and the 128->64 reshard
    must complete over the wire."""
    from repro.data.loader import TransferStats

    hosts, gb = 128, 128
    n_items = gb * 16
    clock = [0.0]
    ck = lambda: clock[0]  # noqa: E731
    transport = LocalTransport()
    coord = FleetCoordinator(
        config=FleetConfig(heartbeat_timeout_s=HEARTBEAT_TIMEOUT,
                           warmup_steps=10_000, cooldown_steps=8,
                           **_search_cfg(True)),
        clock=ck)
    server = CoordinatorServer(coord, transport, owner="coord-0")

    def table_eval(i, j, *, num_batches=16, epoch=0):
        return TransferStats(4.0 / i + 0.1 * j, num_batches, 0)

    items = [np.full((2,), i, np.int32) for i in range(n_items)]
    ds = Dataset(ArrayStorage(items), transform=lambda a: {"x": a})
    agents = [connect_host(
        transport, f"host{h}",
        DataLoader(ds, gb, shuffle=True, seed=13,
                   params=LoaderParams(num_workers=1, prefetch_factor=1),
                   host_index=h, host_count=hosts),
        evaluator=table_eval, clock=ck,
        link_config=LinkConfig(seed=h, jitter=0.0),
        consumes_stream=False) for h in range(hosts)]

    schedule = FleetSchedule([
        FleetEvent(step=2, kind="degrade", host="host0", io_scale=4.0),
        FleetEvent(step=6, kind="leave", host="g64"),
    ])
    alive = list(range(hosts))
    degraded: set = set()
    traffic_mark = None
    t0 = time.perf_counter()
    for step in range(24):
        for e in schedule.at(step):
            if e.kind == "degrade":
                degraded.update(range(8))
            else:                      # half the rack loses power at once
                alive = alive[:hosts // 2]
        if step == 2:                  # steady window start (post-warmup)
            traffic_mark = (dict(transport.kind_msgs), clock[0])
        if step == 6:                  # steady window end, pre-failure
            steady = (transport.kind_msgs.get("report", 0)
                      - traffic_mark[0].get("report", 0),
                      clock[0] - traffic_mark[1])
        clock[0] += 1.0
        for h in alive:
            scale = 4.0 if h in degraded else 1.0
            agents[h].observe(data_s=0.001, step_s=0.02 * scale)
        transport.pump()
        server.tick()
        server.poll()
        if any(e["kind"] == "reshard" for e in coord.events):
            break
    wall_s = time.perf_counter() - t0

    reshard = next((e for e in coord.events if e["kind"] == "reshard"), None)
    assert reshard is not None, "128-host reshard never completed"
    reports_per_host_round = steady[0] / (hosts * steady[1])
    full_avg = (server.report_full_bytes / max(1, server.report_full_msgs))
    delta_avg = (server.report_delta_bytes / max(1, server.report_delta_msgs))
    return {
        "hosts": hosts, "survivors": len(coord.agents),
        "lost": len(reshard["lost"]), "wall_s": wall_s,
        "reports_per_host_round": reports_per_host_round,
        "traffic_linear": bool(reports_per_host_round <= 1.25),
        "full_report_bytes": round(full_avg, 1),
        "delta_report_bytes": round(delta_avg, 1),
        "delta_msgs": server.report_delta_msgs,
        "delta_smaller": bool(server.report_delta_msgs > 0
                              and delta_avg < full_avg),
    }


def run(quick: bool = False) -> List[Dict]:
    n_items = 960 if quick else 1920
    bpe = n_items // GLOBAL_BATCH
    warm = 6 if quick else 12
    window = 12 if quick else 24

    # HA first: the failover outage window is short (2x heartbeat), so it
    # runs before the heavier scenarios leave teardown noise behind
    ha = _ha_failover(quick)
    stress = _stress_128(quick)

    ref = _reference_rate(n_items, quick, window)

    # --- the live fleet ----------------------------------------------------
    clock = [0.0]
    coord = FleetCoordinator(
        config=FleetConfig(heartbeat_timeout_s=HEARTBEAT_TIMEOUT,
                           cooldown_steps=8, warmup_steps=4,
                           **_search_cfg(quick)),
        clock=lambda: clock[0])
    loaders = [_make_host(n_items, h, 3, BASE_LATENCY_S) for h in range(3)]
    agents, streams = [], []
    for h, dl in enumerate(loaders):
        agent = coord.register(HostAgent(
            f"host{h}", dl, evaluator=LoaderEvaluator(dl, to_device=False)))
        agents.append(agent)
        s = dl.stream(to_device=False)
        s._bench_host = f"host{h}"
        streams.append(s)
    delivered: Dict[str, List[np.ndarray]] = {f"host{h}": [] for h in range(3)}
    kw = dict(sink=delivered, clock=clock)

    # startup consensus for the 3-host topology
    coord.request_consensus(reason="startup")
    coord.poll()

    schedule = FleetSchedule([
        FleetEvent(step=warm + window, kind="degrade", host="host1",
                   io_scale=DEGRADE_SCALE),
        FleetEvent(step=warm + 3 * window, kind="leave", host="host2"),
    ])

    _rounds(streams, agents, warm, coord=coord, **kw)
    rate_healthy = _rounds(streams, agents, window, **kw)

    # ... until the schedule degrades host1's storage ...
    for e in schedule.at(warm + window):
        loaders[1]._bench_storage.latency_s *= e.io_scale
    # transition window WITH polls: straggler divergence -> re-consensus
    # (its measured rate includes the retune cost)
    rate_transition = _rounds(streams, agents, window, coord=coord, **kw)
    rate_degraded = _rounds(streams, agents, window, **kw)

    # ... and kills host2: it stops pulling AND stops heartbeating
    schedule.at(warm + 3 * window)
    live_streams, live_agents = streams[:2], agents[:2]
    pre_events = len(coord.events)
    while not any(e["kind"] == "reshard" for e in coord.events[pre_events:]):
        _rounds(live_streams, live_agents, 1, coord=coord, **kw)
    reshard_event = next(e for e in coord.events[pre_events:]
                         if e["kind"] == "reshard")
    coord.poll()                     # the queued post-reshard re-consensus

    _rounds(live_streams, live_agents, warm, coord=coord, **kw)  # settle
    rate_recovered = _rounds(live_streams, live_agents, window, **kw)

    # --- run the epoch out and assert exact coverage ------------------------
    for stream in live_streams:
        while stream.position < bpe:
            batch = next(stream)
            delivered[stream._bench_host].append(
                np.asarray(batch["x"])[:, 0].copy())
    for stream in streams:
        stream.close()
    all_indices = np.concatenate(
        [np.concatenate(chunks) for chunks in delivered.values()
         if chunks])
    # exactly once each: a lost sample leaves a hole, a duplicate a repeat
    counts = np.bincount(all_indices, minlength=n_items)
    coverage_exact = bool((counts == 1).all())
    assert coverage_exact, (
        f"coverage broken across the elastic transition: "
        f"{int((counts == 0).sum())} lost, "
        f"{int((counts > 1).sum())} duplicated of {n_items}")

    recovery = rate_recovered / ref["rate"]
    rows = [
        {"phase": "healthy-3-host", "rate_gbatch_s": round(rate_healthy, 1),
         "note": "coordinator-tuned uniform params"},
        {"phase": "degrade-transition",
         "rate_gbatch_s": round(rate_transition, 1),
         "note": f"host1 storage {DEGRADE_SCALE:.0f}x slower; incl. "
                 "re-consensus cost"},
        {"phase": "degraded-retuned", "rate_gbatch_s": round(rate_degraded, 1),
         "note": "post-consensus steady state"},
        {"phase": "recovered-2-host",
         "rate_gbatch_s": round(rate_recovered, 1),
         "note": f"barrier {reshard_event['barrier']}, "
                 f"{reshard_event['makeup_batches']} makeup batches"},
        {"phase": "reference-2-host", "rate_gbatch_s": round(ref["rate"], 1),
         "note": f"pre-failure N-1 optimum {ref['params']}"},
        {"phase": "failover-steady",
         "rate_gbatch_s": round(ha["rate_steady"], 1),
         "note": "transport-mode fleet, lease-backed leader"},
        {"phase": "failover-outage",
         "rate_gbatch_s": round(ha["rate_outage"], 1),
         "note": f"leader crashed {ha['outage_rounds']} rounds "
                 f"(2x heartbeat timeout); goodput "
                 f"{ha['failover_goodput']:.2f} of steady"},
        {"phase": "failover-promoted",
         "rate_gbatch_s": round(ha["rate_after"], 1),
         "note": f"fence {ha['old_fence']} -> {ha['new_fence']}, "
                 f"stale leader rejected: {ha['stale_rejected']}, "
                 f"coverage exact: {ha['coverage_exact']}"},
        {"phase": "stress-128-host", "rate_gbatch_s": None,
         "note": f"{stress['reports_per_host_round']:.2f} reports/host/"
                 f"round, delta {stress['delta_report_bytes']}B vs full "
                 f"{stress['full_report_bytes']}B, 128->"
                 f"{stress['survivors']} reshard in {stress['wall_s']:.1f}s"},
        {"phase": "gates", "rate_gbatch_s": None,
         "note": f"recovery {recovery:.2f} (>= {GATE_RECOVERY}), "
                 f"failover {ha['failover_goodput']:.2f} "
                 f"(>= {GATE_FAILOVER}), coverage exact: {coverage_exact}"},
    ]

    ha_ok = (ha["fence_fresh"] and ha["stale_rejected"]
             and ha["coverage_exact"] and stress["delta_smaller"]
             and stress["traffic_linear"])
    payload = {
        "bench": "fleet",
        "gate": {
            "required_recovery": GATE_RECOVERY,
            "measured_recovery": round(recovery, 3),
            "coverage_exact": coverage_exact,
            "required_failover_goodput": GATE_FAILOVER,
            "measured_failover_goodput": round(ha["failover_goodput"], 3),
            "failover_fence_fresh": ha["fence_fresh"],
            "failover_stale_leader_rejected": ha["stale_rejected"],
            "failover_coverage_exact": ha["coverage_exact"],
            "stress_delta_smaller_than_full": stress["delta_smaller"],
            "stress_traffic_linear": stress["traffic_linear"],
            "passed": (coverage_exact and recovery >= GATE_RECOVERY
                       and ha_ok
                       and ha["failover_goodput"] >= GATE_FAILOVER),
        },
        "failover": {k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in ha.items()},
        "stress": {k: (round(v, 3) if isinstance(v, float) else v)
                   for k, v in stress.items()},
        "events": [
            {k: (dataclasses.asdict(v) if dataclasses.is_dataclass(v)
                 else v) for k, v in e.items()}
            for e in coord.events],
        "rows": rows,
        "host": {"platform": platform.platform(),
                 "python": sys.version.split()[0],
                 "numpy": np.__version__},
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(payload, f, indent=1, default=str)
        f.write("\n")

    # noise floor for shared CI runners (FASTPATH_GATE_MIN precedent): the
    # honest 0.8 gate lives in the JSON, the hard failure is overridable
    fail_below = float(os.environ.get("FLEET_GATE_MIN", GATE_RECOVERY))
    if recovery < fail_below:
        raise RuntimeError(
            f"fleet recovery gate FAILED: {recovery:.2f} < {fail_below} "
            f"(see {ROOT_JSON})")
    # the HA protocol facts are hard failures at any noise level; only
    # the goodput ratio gets a CI noise floor
    if not ha_ok:
        raise RuntimeError(
            f"fleet HA gate FAILED: fence_fresh={ha['fence_fresh']} "
            f"stale_rejected={ha['stale_rejected']} "
            f"coverage={ha['coverage_exact']} "
            f"delta_smaller={stress['delta_smaller']} "
            f"traffic_linear={stress['traffic_linear']} (see {ROOT_JSON})")
    ha_below = float(os.environ.get("FLEET_HA_GATE_MIN", GATE_FAILOVER))
    if ha["failover_goodput"] < ha_below:
        raise RuntimeError(
            f"fleet failover goodput gate FAILED: "
            f"{ha['failover_goodput']:.2f} < {ha_below} (see {ROOT_JSON})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick="--quick" in sys.argv)))
