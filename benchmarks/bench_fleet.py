"""Elastic fleet gate: degrade + kill a host mid-run, recover goodput.

Runs REAL machinery (thread worker pools over sleep-based LatencyStorage,
live hot-swappable streams, the fleet control plane) through a scheduled
failure scenario:

  phase 1  three hosts, coordinator-tuned uniform params, lockstep rounds;
  phase 2  host1's storage degrades 25x mid-run — the straggler/stall
           signal drives a uniform re-consensus (the transition window's
           rate includes the retune cost: that cost is real);
  phase 3  host2 goes silent — the heartbeat timeout declares it dead, the
           coordinator reshards the survivors at a common barrier (the
           dead host's undelivered slices redistributed as makeup) and
           follows with a re-consensus for the 2-host topology;
  phase 4  the surviving fleet runs the epoch out.

Two gates, both recorded in ``BENCH_fleet.json`` at the repo root (CI
uploads it as a workflow artifact):

* **recovery** — post-failure fleet goodput must reach >= 80% of the
  pre-failure N-1-host optimum (a separately tuned fleet of the two
  surviving host profiles — host0 healthy, host1 degraded — measured with
  the same lockstep driver).  The hard-fail threshold is overridable via
  ``FLEET_GATE_MIN`` for noisy shared CI runners; the honest 0.8 gate is
  what the JSON records.
* **coverage** — every dataset index is delivered exactly once for the
  epoch spanning the elastic transition: the dead host's pre-death
  deliveries + survivors' old-shard batches + makeup + new-shard batches.
  Asserted over the full index multiset, not sampled.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.cluster import FleetEvent, FleetSchedule
from repro.core.dpt import DPTConfig, MultiHostDPT
from repro.core.evaluators import LoaderEvaluator
from repro.data import DataLoader, Dataset, LoaderParams
from repro.data.storage import ArrayStorage, LatencyStorage
from repro.tuning import FleetConfig, FleetCoordinator, HostAgent

TITLE = "Elastic fleet: degrade + kill a host mid-run (recovery gate)"
PAPER_REF = "beyond paper (fleet control plane, DESIGN.md §4)"
GATE_RECOVERY = 0.80
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json")

GLOBAL_BATCH = 12
BASE_LATENCY_S = 1.5e-3
DEGRADE_SCALE = 25.0                # host1's storage latency multiplier
COMPUTE_S = 10e-3                   # synthetic lockstep model step
HEARTBEAT_TIMEOUT = 3.0             # in driver-clock rounds


def _make_host(n_items: int, host: int, host_count: int,
               latency_s: float) -> DataLoader:
    """An index-carrying dataset behind sleep-based storage: thread workers
    see true concurrency, and every delivered sample is accountable."""
    items = [np.full((4,), i, np.int32) for i in range(n_items)]
    storage = LatencyStorage(ArrayStorage(items), latency_s=latency_s,
                             bandwidth=1e9)
    ds = Dataset(storage, transform=lambda a: {"x": a})
    dl = DataLoader(ds, GLOBAL_BATCH, shuffle=True, seed=11,
                    params=LoaderParams(num_workers=2, prefetch_factor=2),
                    host_index=host, host_count=host_count)
    dl._bench_storage = storage     # the degrade event mutates latency_s
    return dl


def _search_cfg(quick: bool) -> Dict:
    return dict(num_cpu_cores=4, num_devices=1, max_prefetch=2,
                retune_budget_batches=5 if quick else 8)


def _rounds(streams: List, agents: Optional[List], rounds: int, *,
            sink: Optional[Dict[str, List]] = None,
            clock: Optional[List[float]] = None,
            coord: Optional[FleetCoordinator] = None) -> float:
    """Drive ``rounds`` lockstep global batches; returns global batches/s.

    Each round pulls one local batch per host (recording delivered indices
    into ``sink``), feeds the agents' goodput monitors, sleeps the
    synthetic compute and advances the fleet clock.  ``coord=None`` skips
    the decide step — measurement windows are poll-free so a re-consensus
    never lands inside the rate being gated (transition windows pass the
    coordinator and pay retune cost where it belongs)."""
    t0 = time.perf_counter()
    for _ in range(rounds):
        if clock is not None:
            clock[0] += 1.0
        for i, stream in enumerate(streams):
            t1 = time.perf_counter()
            batch = next(stream)
            data_s = time.perf_counter() - t1
            if sink is not None:
                sink[stream._bench_host].append(
                    np.asarray(batch["x"])[:, 0].copy())
            if agents is not None:
                agents[i].observe(data_s=data_s, step_s=data_s + COMPUTE_S)
        time.sleep(COMPUTE_S)
        if coord is not None:
            coord.poll()
    return rounds / (time.perf_counter() - t0)


def _reference_rate(n_items: int, quick: bool, window: int) -> Dict:
    """The pre-failure N-1-host optimum: a fresh fleet of the two SURVIVOR
    profiles (host0 healthy, host1 degraded), consensus-tuned, measured
    with the same lockstep driver."""
    latencies = [BASE_LATENCY_S, BASE_LATENCY_S * DEGRADE_SCALE]
    loaders = [_make_host(n_items, h, 2, lat)
               for h, lat in enumerate(latencies)]
    scfg = _search_cfg(quick)
    dpt_cfg = DPTConfig(num_cpu_cores=scfg["num_cpu_cores"],
                        num_devices=scfg["num_devices"],
                        max_prefetch=scfg["max_prefetch"],
                        num_batches=scfg["retune_budget_batches"])
    fleet = MultiHostDPT(
        [LoaderEvaluator(dl, to_device=False) for dl in loaders],
        dpt_cfg).run_uniform()
    for dl in loaders:
        dl.with_params(dl.params.replace(
            num_workers=fleet.uniform_params[0],
            prefetch_factor=fleet.uniform_params[1]))
    streams = []
    for h, dl in enumerate(loaders):
        s = dl.stream(to_device=False)
        s._bench_host = f"ref{h}"
        streams.append(s)
    _rounds(streams, None, max(4, window // 3))          # warm the pipeline
    rate = _rounds(streams, None, window)
    for s in streams:
        s.close()
    return {"rate": rate, "params": fleet.uniform_params}


def run(quick: bool = False) -> List[Dict]:
    n_items = 960 if quick else 1920
    bpe = n_items // GLOBAL_BATCH
    warm = 6 if quick else 12
    window = 12 if quick else 24

    ref = _reference_rate(n_items, quick, window)

    # --- the live fleet ----------------------------------------------------
    clock = [0.0]
    coord = FleetCoordinator(
        config=FleetConfig(heartbeat_timeout_s=HEARTBEAT_TIMEOUT,
                           cooldown_steps=8, warmup_steps=4,
                           **_search_cfg(quick)),
        clock=lambda: clock[0])
    loaders = [_make_host(n_items, h, 3, BASE_LATENCY_S) for h in range(3)]
    agents, streams = [], []
    for h, dl in enumerate(loaders):
        agent = coord.register(HostAgent(
            f"host{h}", dl, evaluator=LoaderEvaluator(dl, to_device=False)))
        agents.append(agent)
        s = dl.stream(to_device=False)
        s._bench_host = f"host{h}"
        streams.append(s)
    delivered: Dict[str, List[np.ndarray]] = {f"host{h}": [] for h in range(3)}
    kw = dict(sink=delivered, clock=clock)

    # startup consensus for the 3-host topology
    coord.request_consensus(reason="startup")
    coord.poll()

    schedule = FleetSchedule([
        FleetEvent(step=warm + window, kind="degrade", host="host1",
                   io_scale=DEGRADE_SCALE),
        FleetEvent(step=warm + 3 * window, kind="leave", host="host2"),
    ])

    _rounds(streams, agents, warm, coord=coord, **kw)
    rate_healthy = _rounds(streams, agents, window, **kw)

    # ... until the schedule degrades host1's storage ...
    for e in schedule.at(warm + window):
        loaders[1]._bench_storage.latency_s *= e.io_scale
    # transition window WITH polls: straggler divergence -> re-consensus
    # (its measured rate includes the retune cost)
    rate_transition = _rounds(streams, agents, window, coord=coord, **kw)
    rate_degraded = _rounds(streams, agents, window, **kw)

    # ... and kills host2: it stops pulling AND stops heartbeating
    schedule.at(warm + 3 * window)
    live_streams, live_agents = streams[:2], agents[:2]
    pre_events = len(coord.events)
    while not any(e["kind"] == "reshard" for e in coord.events[pre_events:]):
        _rounds(live_streams, live_agents, 1, coord=coord, **kw)
    reshard_event = next(e for e in coord.events[pre_events:]
                         if e["kind"] == "reshard")
    coord.poll()                     # the queued post-reshard re-consensus

    _rounds(live_streams, live_agents, warm, coord=coord, **kw)  # settle
    rate_recovered = _rounds(live_streams, live_agents, window, **kw)

    # --- run the epoch out and assert exact coverage ------------------------
    for stream in live_streams:
        while stream.position < bpe:
            batch = next(stream)
            delivered[stream._bench_host].append(
                np.asarray(batch["x"])[:, 0].copy())
    for stream in streams:
        stream.close()
    all_indices = np.concatenate(
        [np.concatenate(chunks) for chunks in delivered.values()
         if chunks])
    # exactly once each: a lost sample leaves a hole, a duplicate a repeat
    counts = np.bincount(all_indices, minlength=n_items)
    coverage_exact = bool((counts == 1).all())
    assert coverage_exact, (
        f"coverage broken across the elastic transition: "
        f"{int((counts == 0).sum())} lost, "
        f"{int((counts > 1).sum())} duplicated of {n_items}")

    recovery = rate_recovered / ref["rate"]
    rows = [
        {"phase": "healthy-3-host", "rate_gbatch_s": round(rate_healthy, 1),
         "note": "coordinator-tuned uniform params"},
        {"phase": "degrade-transition",
         "rate_gbatch_s": round(rate_transition, 1),
         "note": f"host1 storage {DEGRADE_SCALE:.0f}x slower; incl. "
                 "re-consensus cost"},
        {"phase": "degraded-retuned", "rate_gbatch_s": round(rate_degraded, 1),
         "note": "post-consensus steady state"},
        {"phase": "recovered-2-host",
         "rate_gbatch_s": round(rate_recovered, 1),
         "note": f"barrier {reshard_event['barrier']}, "
                 f"{reshard_event['makeup_batches']} makeup batches"},
        {"phase": "reference-2-host", "rate_gbatch_s": round(ref["rate"], 1),
         "note": f"pre-failure N-1 optimum {ref['params']}"},
        {"phase": "gates", "rate_gbatch_s": None,
         "note": f"recovery {recovery:.2f} (>= {GATE_RECOVERY}), "
                 f"coverage exact: {coverage_exact}"},
    ]

    payload = {
        "bench": "fleet",
        "gate": {
            "required_recovery": GATE_RECOVERY,
            "measured_recovery": round(recovery, 3),
            "coverage_exact": coverage_exact,
            "passed": coverage_exact and recovery >= GATE_RECOVERY,
        },
        "events": [
            {k: (dataclasses.asdict(v) if dataclasses.is_dataclass(v)
                 else v) for k, v in e.items()}
            for e in coord.events],
        "rows": rows,
        "host": {"platform": platform.platform(),
                 "python": sys.version.split()[0],
                 "numpy": np.__version__},
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(payload, f, indent=1, default=str)
        f.write("\n")

    # noise floor for shared CI runners (FASTPATH_GATE_MIN precedent): the
    # honest 0.8 gate lives in the JSON, the hard failure is overridable
    fail_below = float(os.environ.get("FLEET_GATE_MIN", GATE_RECOVERY))
    if recovery < fail_below:
        raise RuntimeError(
            f"fleet recovery gate FAILED: {recovery:.2f} < {fail_below} "
            f"(see {ROOT_JSON})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick="--quick" in sys.argv)))
