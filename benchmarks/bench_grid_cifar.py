"""Paper Fig 2a / 2b / 4 — CIFAR-10 grid search.

Fig 2a: normalized transfer time while increasing workers (several prefetch
factors), vs the PyTorch-default line (6 workers, prefetch 2).
Fig 2b: prefetch-factor fluctuation at the optimal worker count.
Fig 4:  the full (workers x prefetch) grid DPT searches.

Paper claims reproduced: optimum at ~10 workers (12 logical cores minus the
main + loader processes), ~1.3x over the default; prefetch fluctuation is
small but non-monotone (must be searched).
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.core import (DPT, DPTConfig, LoaderSimulator, MachineProfile,
                        SimulatorEvaluator, default_params)
from repro.data.storage import cifar10_profile

TITLE = "CIFAR-10 grid search (workers x prefetch)"
PAPER_REF = "Fig 2a/2b/4"

MACHINE = MachineProfile()          # paper testbed: i7-8700K, 64 GB, 1 GPU
BATCH = 32                          # paper: "usually used when using CIFAR-10"


def run(quick: bool = False) -> List[Dict]:
    sim = LoaderSimulator(cifar10_profile(), MACHINE)
    ev = SimulatorEvaluator(sim, batch_size=BATCH)
    cfg = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=8,
                    num_batches=32 if quick else 64, epoch=1)
    dpt = DPT(ev, cfg)

    # --- Algorithm 1 run (what DPT itself would do) -------------------------
    res = dpt.run()
    rows: List[Dict] = [{
        "figure": "alg1", "nworker": res.nworker, "nprefetch": res.nprefetch,
        "optimal_s": res.optimal_time, "default_s": res.default_time,
        "speedup_vs_default": res.speedup_vs_default,
        "cells_measured": len(res.trials),
    }]

    # --- Fig 2a: worker sweep at several prefetch factors -------------------
    workers = range(1, 13 if quick else 49)
    prefetches = (1, 2, 4, 8)
    grid = dpt.grid(list(workers), list(prefetches))
    dw, dp = default_params(12)
    t_default = grid.get((dw, dp)) or ev(dw, dp, num_batches=cfg.num_batches,
                                         epoch=1).seconds
    for j in prefetches:
        col = {w: grid[(w, j)] for w in workers if math.isfinite(grid[(w, j)])}
        worst = max(col.values())
        best_w = min(col, key=col.get)
        rows.append({
            "figure": "2a", "prefetch": j, "best_worker": best_w,
            "best_s": col[best_w], "norm_best": col[best_w] / worst,
            "default_s": t_default,
            "speedup_vs_default": t_default / col[best_w],
        })

    # --- Fig 2b: prefetch sweep at the optimal worker count -----------------
    best_w = res.nworker
    pf_ts = {j: ev(best_w, j, num_batches=cfg.num_batches, epoch=1).seconds
             for j in range(1, 9)}
    worst = max(pf_ts.values())
    for j, t in pf_ts.items():
        rows.append({"figure": "2b", "worker": best_w, "prefetch": j,
                     "seconds": t, "normalized": t / worst})
    fluct = (max(pf_ts.values()) - min(pf_ts.values())) / min(pf_ts.values())
    rows.append({"figure": "2b-summary", "worker": best_w,
                 "prefetch_fluctuation_pct": 100 * fluct,
                 "best_prefetch": min(pf_ts, key=pf_ts.get)})

    # --- Fig 4: full grid (coarse dump: best/worst per worker) --------------
    for w in (list(workers) if quick else [1, 2, 4, 6, 8, 10, 12, 16, 24, 48]):
        col = {j: grid.get((w, j)) for j in prefetches
               if grid.get((w, j)) is not None}
        col = {j: t for j, t in col.items() if math.isfinite(t)}
        if not col:
            continue
        rows.append({"figure": "4", "worker": w,
                     "best_prefetch": min(col, key=col.get),
                     "best_s": min(col.values()),
                     "worst_s": max(col.values())})
    return rows


def main() -> None:
    from benchmarks.common import fmt_table, save_rows
    rows = run()
    print(f"== {TITLE} ({PAPER_REF}) ==")
    print(fmt_table([r for r in rows if r["figure"] == "alg1"]))
    print(fmt_table([r for r in rows if r["figure"] == "2a"]))
    print(save_rows("grid_cifar", rows))


if __name__ == "__main__":
    main()
