"""A/B gate for the IO-locality fast path (DESIGN.md §5).

PR 2's zero-copy path made collation cheap; on a cold cache the remaining
epoch cost is *where* the sampler sends reads — a fully random order
defeats ``read_batch`` coalescing (every item is its own storage request),
while ``locality_chunk`` shuffling turns a batch into a handful of
contiguous runs that each cost ONE request.  This bench runs the SAME
cold-cache ``LatencyStorage`` dataset through both orders at equal
(num_workers, prefetch_factor) and gates on the chunked order delivering
>= 2x host batches/sec, with three correctness riders:

* the chunked epoch's sample multiset is byte-identical to the random
  epoch's (chunking reorders, it never re-samples);
* shuffle quality holds: the adjacent-pair rate of the chunked permutation
  stays under the chunk-predicted ceiling (~2.5/C — far from sequential);
* a DPT grid over (workers, prefetch_factor, locality_chunk) picks a
  chunked config on the cold profile (the third axis resolves).

Results land in ``artifacts/bench/locality.json`` plus ``BENCH_locality
.json`` at the repo root (uploaded as a CI artifact), mirroring the
fastpath/fleet gates.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import sys

import numpy as np

from repro.core.dpt import DPTConfig
from repro.core.evaluators import LoaderEvaluator
from repro.data import DataLoader, LoaderParams
from repro.data.dataset import Dataset, image_transform
from repro.data.sampler import ShardedSampler
from repro.data.storage import ArrayStorage, LatencyStorage
from repro.tuning import tune

TITLE = "IO-locality fast path A/B (cold-cache host batches/sec)"
PAPER_REF = "perf gate"
GATE_SPEEDUP = 2.0
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_locality.json")

BATCH = 64
CHUNK = 64          # = BATCH: each global batch covers whole chunks


def _cold_dataset(n: int, *, latency_s: float = 1.2e-3) -> Dataset:
    """Seek-bound cold storage: every read pays a real (GIL-releasing)
    base latency, cache disabled so EVERY epoch is a cold epoch — the
    regime the paper's Table 1b cold column measures."""
    rng = np.random.default_rng(0)
    items = [rng.integers(0, 255, (32, 32, 3), dtype=np.uint8)
             for _ in range(n)]
    storage = LatencyStorage(ArrayStorage(items), latency_s=latency_s,
                             bandwidth=2e9, cache_bytes=0)
    return Dataset(storage, transform=image_transform)


def _ab_batches_per_s(ds, *, num_batches, repeats):
    """Best-of-N cold-epoch delivery rate, random vs chunked order, at
    EQUAL (num_workers, prefetch_factor).  Repeats interleaved so a load
    spike degrades both sides instead of skewing the ratio; the locality
    override measures both orders through one loader (same storage, same
    machinery)."""
    params = LoaderParams(num_workers=2, prefetch_factor=2,
                          fast_path=True, zero_copy=True)
    dl = DataLoader(ds, BATCH, params=params, shuffle=True, seed=0)
    dl.measure_transfer_time(4, epoch=0, to_device=False)      # warmup
    best = {"random": 0.0, "chunked": 0.0}
    run_len = {"random": 0.0, "chunked": 0.0}
    for rep in range(repeats):
        for name, chunk in (("random", 0), ("chunked", CHUNK)):
            st = dl.measure_transfer_time(num_batches, epoch=1 + rep,
                                          to_device=False,
                                          locality_chunk=chunk)
            best[name] = max(best[name], st.batches / st.seconds)
            run_len[name] = max(run_len[name], st.coalesced_run_len)
    return best, run_len


def _epoch_sample_digests(ds, *, locality_chunk, num_batches):
    """Sorted per-sample digests of one delivered epoch (order-free)."""
    params = LoaderParams(num_workers=0, fast_path=True,
                          locality_chunk=locality_chunk)
    dl = DataLoader(ds, BATCH, params=params, shuffle=True, seed=0)
    digests = []
    for batch in dl.host_batches(epoch=0, num_batches=num_batches):
        for row in np.asarray(batch["image"]):
            digests.append(hashlib.sha1(row.tobytes()).hexdigest())
    return sorted(digests)


def adjacent_pair_ceiling(chunk: int) -> float:
    """Chunk-predicted ceiling for the adjacent-pair rate: a uniform
    within-chunk shuffle leaves ~1 consecutive-value succession per chunk
    (expected rate 1/C); 2.5/C covers sampling noise with wide margin
    while still being ~40x below a sequential order's rate of 1.0."""
    return 2.5 / max(2, chunk)


# --------------------------------------------------------------------------
# multi-host layout gate (DESIGN.md §6): host-major keeps whole chunks on
# one host; the legacy strided layout dilutes per-host runs toward ~1
# --------------------------------------------------------------------------
MULTIHOST_CHUNK = 16       # <= local batch at every gated host count
MULTIHOST_GATE = 0.5       # host-major per-host run length >= 0.5 * C


def per_host_run_len(n: int, *, hosts: int, chunk: int,
                     layout: str) -> float:
    """Mean achieved per-host coalesced run length (items per storage
    request) over one epoch, straight from the sampler's index streams —
    the quantity LatencyStorage.achieved_run_len measures on real reads."""
    from repro.data.storage import coalesce_runs
    shards = [ShardedSampler(n, BATCH, seed=0, locality_chunk=chunk,
                             host_index=h, host_count=hosts, layout=layout)
              for h in range(hosts)]
    requests = sum(len(coalesce_runs(s.local_indices(0, b)))
                   for s in shards for b in range(n // BATCH))
    return n / requests


def multihost_rows(n: int):
    """Gate rows: at H in {2, 4}, host-major keeps per-host run length
    >= 0.5*C while the strided baseline collapses (< 0.5*C)."""
    rows = []
    for hosts in (2, 4):
        major = per_host_run_len(n, hosts=hosts, chunk=MULTIHOST_CHUNK,
                                 layout="host_major")
        strided = per_host_run_len(n, hosts=hosts, chunk=MULTIHOST_CHUNK,
                                   layout="strided")
        floor = MULTIHOST_GATE * MULTIHOST_CHUNK
        assert major >= floor, \
            (f"host-major per-host run length {major:.2f} < {floor} "
             f"at H={hosts} (C={MULTIHOST_CHUNK})")
        assert strided < floor, \
            (f"strided baseline unexpectedly kept locality at H={hosts}: "
             f"{strided:.2f} >= {floor}")
        rows.append({"hosts": hosts, "chunk": MULTIHOST_CHUNK,
                     "host_major_run_len": round(major, 2),
                     "strided_run_len": round(strided, 2),
                     "required_min": floor, "passed": major >= floor})
    return rows


def run(quick: bool = False):
    n = 1024 if quick else 2048
    num_batches = n // BATCH
    repeats = 2 if quick else 3
    ds = _cold_dataset(n)

    # --- correctness riders first: identity + shuffle quality -------------
    random_digests = _epoch_sample_digests(
        ds, locality_chunk=0, num_batches=num_batches)
    chunked_digests = _epoch_sample_digests(
        ds, locality_chunk=CHUNK, num_batches=num_batches)
    assert random_digests == chunked_digests, \
        "chunked epoch is not the random epoch's sample multiset"

    perm = ShardedSampler(n, BATCH, seed=0,
                          locality_chunk=CHUNK)._epoch_perm(0)
    adj_rate = float(np.mean(perm[1:] == perm[:-1] + 1))
    adj_ceiling = adjacent_pair_ceiling(CHUNK)
    assert adj_rate <= adj_ceiling, \
        f"shuffle-quality bound violated: {adj_rate:.4f} > {adj_ceiling:.4f}"

    # --- the A/B gate ------------------------------------------------------
    best, run_len = _ab_batches_per_s(ds, num_batches=num_batches,
                                      repeats=repeats)
    speedup = best["chunked"] / best["random"]

    # --- the DPT third axis resolves on the cold profile -------------------
    dl = DataLoader(ds, BATCH, params=LoaderParams(fast_path=True),
                    shuffle=True, seed=0)
    cfg = DPTConfig(num_cpu_cores=2, num_devices=2, min_prefetch=1,
                    max_prefetch=2, num_batches=min(8, num_batches),
                    epoch=0, locality_chunks=(0, CHUNK))
    pick = tune(evaluator=LoaderEvaluator(dl, to_device=False),
                strategy="grid", config=cfg, measure_default=False)
    assert pick.locality_chunk == CHUNK, \
        f"DPT grid picked locality {pick.locality_chunk}, expected {CHUNK}"

    # --- multi-host layout gate (host-major vs strided, DESIGN.md §6) ------
    mh_rows = multihost_rows(n)

    rows = [{"order": "random", "workers": 2, "prefetch": 2,
             "bps": round(best["random"], 1),
             "run_len": round(run_len["random"], 2)},
            {"order": "chunked", "workers": 2, "prefetch": 2,
             "bps": round(best["chunked"], 1),
             "run_len": round(run_len["chunked"], 2),
             "speedup_x": round(speedup, 2)}]

    payload = {
        "bench": "locality",
        "gate": {"profile": "cold_cache_latency", "chunk": CHUNK,
                 "required_speedup_x": GATE_SPEEDUP,
                 "measured_speedup_x": round(speedup, 2),
                 "passed": speedup >= GATE_SPEEDUP,
                 "byte_identical_multiset": True,
                 "adjacent_pair_rate": round(adj_rate, 5),
                 "adjacent_pair_ceiling": round(adj_ceiling, 5),
                 "dpt_pick": {"nworker": pick.nworker,
                              "nprefetch": pick.nprefetch,
                              "locality_chunk": pick.locality_chunk}},
        "multihost": {"chunk": MULTIHOST_CHUNK,
                      "required_run_len_min": MULTIHOST_GATE
                      * MULTIHOST_CHUNK,
                      "rows": mh_rows},
        "rows": rows,
        "host": {"platform": platform.platform(),
                 "python": sys.version.split()[0],
                 "numpy": np.__version__},
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")

    # honest 2x gate in the JSON; the hard failure floor is overridable so
    # noisy shared CI runners don't red-flag PRs on timing variance
    fail_below = float(os.environ.get("LOCALITY_GATE_MIN", GATE_SPEEDUP))
    if speedup < fail_below:
        raise RuntimeError(
            f"locality gate FAILED: {speedup:.2f}x < {fail_below}x "
            f"chunked-vs-random on the cold-cache profile (see {ROOT_JSON})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick="--quick" in sys.argv)))
