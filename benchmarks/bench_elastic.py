"""Elastic batch geometry gate: per-host consensus + death-rescale.

Two scenarios, both on REAL machinery (thread worker pools, live
hot-swappable streams, the fleet control plane), recorded in
``BENCH_elastic.json`` at the repo root (CI uploads it as an artifact):

* **per-host vs uniform goodput** — a 2x-heterogeneous two-host fleet
  (host1's sleep-based storage is 2x slower per sample) is tuned twice
  from identical starts: once with the classic uniform consensus (one
  fleet-wide cell, even batch split) and once with
  ``FleetConfig.consensus="per_host"`` (each host adopts its own DPT
  optimum and the batch partition is re-apportioned to the measured
  per-host rates, so the fast host takes the larger contiguous
  host-major slice).  A lockstep fleet runs at the max host time, so
  moving work onto the fast host must raise fleet goodput: the gate is
  **per-host >= 1.3x uniform** (hard-fail floor overridable via
  ``ELASTIC_GATE_MIN`` for noisy shared CI runners; the honest 1.3 gate
  is what the JSON records).  The re-apportioned epoch must still cover
  every index exactly once — asserted over the full multiset,
  unconditionally.

* **death rescale** — a 4-host fleet at global batch 12 loses a host to
  heartbeat timeout.  ``plan_remesh`` keeps the per-replica batch (12/4
  = 3) and the reshard latches the planned global batch 9 at the next
  epoch boundary no producer has crossed (DESIGN.md §11).  Asserted
  unconditionally: the event log carries the plan + latch epoch, every
  survivor's live loader reports ``global_batch == 9`` (local 3) after
  the latch, and every epoch through the transition covers each index
  exactly once — the pre-latch epochs at the old geometry (with the
  corpse's unconsumed slices redistributed as makeup) and the first
  epoch at the new geometry.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.evaluators import LoaderEvaluator
from repro.data import DataLoader, Dataset, LoaderParams
from repro.data.loader import TransferStats
from repro.data.storage import ArrayStorage, LatencyStorage
from repro.tuning import FleetConfig, FleetCoordinator, HostAgent

TITLE = "Elastic geometry: per-host consensus + death rescale"
PAPER_REF = "beyond paper (elastic batch geometry, DESIGN.md §11)"
GATE_RATIO = 1.3                    # per-host goodput vs uniform consensus
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_elastic.json")

GLOBAL_BATCH = 12
BASE_LATENCY_S = 4e-3
HET_SCALE = 2.0                     # host1's per-sample latency multiplier
COMPUTE_S = 1e-3                    # synthetic lockstep model step


def _search_cfg(quick: bool) -> Dict:
    return dict(num_cpu_cores=4, num_devices=1, max_prefetch=2,
                retune_budget_batches=4 if quick else 6)


def _make_host(n_items: int, host: int, host_count: int,
               latency_s: float) -> DataLoader:
    """An index-carrying dataset behind sleep-based storage: thread workers
    see true concurrency, and every delivered sample is accountable."""
    items = [np.full((4,), i, np.int32) for i in range(n_items)]
    storage = LatencyStorage(ArrayStorage(items), latency_s=latency_s,
                             bandwidth=1e9)
    ds = Dataset(storage, transform=lambda a: {"x": a})
    return DataLoader(ds, GLOBAL_BATCH, shuffle=True, seed=11,
                      params=LoaderParams(num_workers=2, prefetch_factor=2),
                      host_index=host, host_count=host_count)


def _lockstep(streams: List, rounds: int,
              sink: Optional[List[np.ndarray]] = None) -> float:
    """Drive ``rounds`` lockstep global batches; returns global batches/s.
    Measurement windows are poll-free — the consensus cost is paid before
    the window, where the comparison is fair to both modes."""
    t0 = time.perf_counter()
    for _ in range(rounds):
        for s in streams:
            batch = next(s)
            if sink is not None:
                sink.append(np.asarray(batch["x"])[:, 0].copy())
        time.sleep(COMPUTE_S)
    return rounds / (time.perf_counter() - t0)


def _hetero_rate(consensus: str, n_items: int, quick: bool) -> Dict:
    """Build the 2x-heterogeneous fleet, run one forced consensus in the
    given mode, measure the steady lockstep rate past the apply barrier,
    then run out to an epoch boundary and check exact coverage."""
    bpe = n_items // GLOBAL_BATCH
    warm = 6 if quick else 10
    window = 12 if quick else 24

    coord = FleetCoordinator(config=FleetConfig(
        heartbeat_timeout_s=1e9, warmup_steps=10_000, cooldown_steps=8,
        consensus=consensus, **_search_cfg(quick)))
    latencies = [BASE_LATENCY_S, BASE_LATENCY_S * HET_SCALE]
    agents, streams = [], []
    for h, lat in enumerate(latencies):
        dl = _make_host(n_items, h, len(latencies), lat)
        agents.append(coord.register(HostAgent(
            f"host{h}", dl, evaluator=LoaderEvaluator(dl, to_device=False))))
        streams.append(dl.stream(to_device=False))
    delivered: List[np.ndarray] = []
    try:
        coord.request_consensus(reason="startup")
        actions = coord.poll()
        event = next((a for a in actions if a["kind"] == "consensus"), {})
        # a per-host repartition applies at a negotiated common barrier:
        # drain past it (plus pipeline warm-up) before the gated window
        barrier = int(event.get("barrier") or 0)
        while streams[0].position < barrier:
            _lockstep(streams, 1, sink=delivered)
        _lockstep(streams, warm, sink=delivered)
        rate = _lockstep(streams, window, sink=delivered)
        # run out to an epoch boundary: the (possibly mid-epoch) partition
        # change must keep once-per-epoch delivery exact
        epochs = -(-streams[0].position // bpe)
        for s in streams:
            while s.position < epochs * bpe:
                delivered.append(np.asarray(next(s)["x"])[:, 0].copy())
        counts = np.bincount(np.concatenate(delivered), minlength=n_items)
        sizes = [a.loader.sampler.local_batch for a in agents]
        return {"mode": consensus, "rate": rate, "sizes": sizes,
                "params": event.get("params"),
                "applied": bool(event.get("applied")),
                "coverage_exact": bool((counts == epochs).all()),
                "epochs": int(epochs)}
    finally:
        for s in streams:
            s.close()


def _death_rescale(quick: bool) -> Dict:
    """4 hosts at global batch 12 lose one to heartbeat timeout: the
    reshard must latch plan_remesh's rescaled batch (9, per-replica kept
    at 3) at an epoch boundary with exact coverage through the
    transition.  Correctness facts only — a table evaluator stands in
    for measurement so the scenario is deterministic and cheap."""
    del quick                       # correctness scenario: one size
    gb, bpe, hosts = GLOBAL_BATCH, 6, 4
    n_items = gb * bpe
    timeout = 4.0
    clock = [0.0]
    coord = FleetCoordinator(
        config=FleetConfig(heartbeat_timeout_s=timeout, warmup_steps=10_000,
                           cooldown_steps=8, **_search_cfg(True)),
        clock=lambda: clock[0])

    def table_eval(i, j, *, num_batches=16, epoch=0):
        return TransferStats(4.0 / i + 0.1 * j, num_batches, 0)

    items = [np.full((4,), i, np.int32) for i in range(n_items)]
    ds = Dataset(ArrayStorage(items), transform=lambda a: {"x": a})
    agents, streams = {}, {}
    for h in range(hosts):
        dl = DataLoader(ds, gb, shuffle=True, seed=7,
                        params=LoaderParams(num_workers=2, prefetch_factor=2),
                        host_index=h, host_count=hosts)
        name = f"host{h}"
        agents[name] = coord.register(HostAgent(name, dl,
                                                evaluator=table_eval))
        streams[name] = dl.stream(to_device=False)
    delivered: List[np.ndarray] = []
    alive = sorted(set(agents) - {"host3"})
    try:
        for _ in range(3):          # a few healthy lockstep rounds
            clock[0] += 1.0
            for name in sorted(agents):
                delivered.append(
                    np.asarray(next(streams[name])["x"])[:, 0].copy())
                agents[name].observe(data_s=0.001, step_s=0.05)
            coord.poll()
        for _ in range(int(timeout) + 2):   # host3 goes silent
            clock[0] += 1.0
            for name in alive:
                agents[name].observe(data_s=0.001, step_s=0.05)
            coord.poll()
        event = next(e for e in coord.events if e["kind"] == "reshard")
        plan_gb = int(event["plan"].new_global_batch)
        ge = event["geometry_epoch"]
        # drain the pre-latch epochs (old geometry + makeup) plus one full
        # epoch at the NEW geometry
        for name in alive:
            s = streams[name]
            while s.position < ge * bpe + n_items // plan_gb:
                delivered.append(np.asarray(next(s)["x"])[:, 0].copy())
        counts = np.bincount(np.concatenate(delivered), minlength=n_items)
        return {
            "old_global_batch": gb, "new_global_batch": plan_gb,
            "geometry_epoch": None if ge is None else int(ge),
            "latched": bool(ge is not None and ge >= 1),
            "rescale_applied": all(
                agents[name].loader.global_batch == plan_gb
                and agents[name].loader.sampler.local_batch
                == plan_gb // len(alive) for name in alive),
            "makeup_batches": int(event["makeup_batches"]),
            "barrier": int(event["barrier"]),
            "coverage_exact": bool((counts == (ge + 1)).all()),
            "lost": int((counts < ge + 1).sum()),
            "dup": int((counts > ge + 1).sum()),
            "n_items": n_items, "epochs": int(ge + 1),
        }
    finally:
        for s in streams.values():
            s.close()


def run(quick: bool = False) -> List[Dict]:
    n_items = 360 if quick else 720

    death = _death_rescale(quick)
    uniform = _hetero_rate("uniform", n_items, quick)
    per_host = _hetero_rate("per_host", n_items, quick)

    ratio = per_host["rate"] / uniform["rate"]
    sizes = per_host["sizes"]
    # the fast host (host0) must hold the strictly larger slice, and the
    # partition must still sum to the global batch
    rebalanced = (sum(sizes) == GLOBAL_BATCH and sizes[0] > sizes[1])

    rows = [
        {"phase": "uniform-consensus",
         "rate_gbatch_s": round(uniform["rate"], 1),
         "note": f"even split {uniform['sizes']}, cell "
                 f"{uniform['params']}"},
        {"phase": "per-host-consensus",
         "rate_gbatch_s": round(per_host["rate"], 1),
         "note": f"rate-apportioned split {sizes}, cells "
                 f"{per_host['params']}"},
        {"phase": "death-rescale", "rate_gbatch_s": None,
         "note": f"4->3 hosts: global batch {death['old_global_batch']} -> "
                 f"{death['new_global_batch']} latched at epoch "
                 f"{death['geometry_epoch']}, {death['makeup_batches']} "
                 f"makeup batches"},
        {"phase": "gates", "rate_gbatch_s": None,
         "note": f"per-host/uniform {ratio:.2f} (>= {GATE_RATIO}), "
                 f"coverage exact: {per_host['coverage_exact']} / "
                 f"{death['coverage_exact']}, rescale applied: "
                 f"{death['rescale_applied']}"},
    ]

    facts_ok = (rebalanced and per_host["coverage_exact"]
                and uniform["coverage_exact"] and death["latched"]
                and death["rescale_applied"] and death["coverage_exact"]
                and death["new_global_batch"] == 9)
    payload = {
        "bench": "elastic",
        "gate": {
            "required_ratio": GATE_RATIO,
            "measured_ratio": round(ratio, 3),
            "sizes_rebalanced": rebalanced,
            "per_host_coverage_exact": per_host["coverage_exact"],
            "uniform_coverage_exact": uniform["coverage_exact"],
            "death_rescale_applied": death["rescale_applied"],
            "death_geometry_latched": death["latched"],
            "death_coverage_exact": death["coverage_exact"],
            "passed": bool(facts_ok and ratio >= GATE_RATIO),
        },
        "uniform": {k: (round(v, 3) if isinstance(v, float) else v)
                    for k, v in uniform.items()},
        "per_host": {k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in per_host.items()},
        "death": death,
        "rows": rows,
        "host": {"platform": platform.platform(),
                 "python": sys.version.split()[0],
                 "numpy": np.__version__},
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(payload, f, indent=1, default=str)
        f.write("\n")

    # the protocol facts are hard failures at any noise level; only the
    # goodput ratio gets a CI noise floor (FASTPATH_GATE_MIN precedent)
    if not facts_ok:
        raise RuntimeError(
            f"elastic gate FAILED: sizes={sizes} "
            f"coverage={per_host['coverage_exact']}/"
            f"{death['coverage_exact']} "
            f"rescale={death['rescale_applied']} "
            f"new_gb={death['new_global_batch']} (see {ROOT_JSON})")
    fail_below = float(os.environ.get("ELASTIC_GATE_MIN", GATE_RATIO))
    if ratio < fail_below:
        raise RuntimeError(
            f"elastic goodput gate FAILED: per-host/uniform {ratio:.2f} "
            f"< {fail_below} (see {ROOT_JSON})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick="--quick" in sys.argv)))
