"""A/B gate for the cross-epoch cache tier (DESIGN.md §7).

PR 4/5 made cold epochs cheap to *order* (locality chunking); what they
cannot remove is the storage latency itself — every epoch re-pays it.
The cache tier retains raw items across epochs under an explicit byte
budget, so epochs 2+ stream at memory speed.  This bench runs the SAME
cold-cache ``LatencyStorage`` dataset through the tier at equal
(num_workers, prefetch_factor) and gates on the warm epoch delivering
>= 3x the cold epoch's host batches/sec, with three correctness riders:

* the cached stream's per-epoch sample multiset is byte-identical to the
  cache-off stream's (the hot/cold interleave reorders, it never
  re-samples, and hits are the bytes that were admitted);
* the warm epoch's hit/miss split is exact: every read a hit, zero
  misses (and the cold epoch the reverse) — the ``TransferStats``
  counters the tuner prices the axis with;
* a 4-axis DPT grid (workers, prefetch, chunk axis off, budget) picks a
  non-zero budget on the cold profile at a warm epoch, and the simulator
  prices the same knob the same way on a RAM-tight machine profile.

Results land in ``artifacts/bench/cache.json`` plus ``BENCH_cache.json``
at the repo root (uploaded as a CI artifact), mirroring the
fastpath/locality/fleet gates.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import sys

import numpy as np

from repro.core.dpt import DPTConfig
from repro.core.evaluators import LoaderEvaluator, SimulatorEvaluator
from repro.core.simulator import LoaderSimulator, MachineProfile
from repro.data import DataLoader, LoaderParams
from repro.data.dataset import Dataset, image_transform
from repro.data.storage import ArrayStorage, LatencyStorage, StorageProfile
from repro.tuning import tune

TITLE = "Cross-epoch cache tier A/B (cold vs warm host batches/sec)"
PAPER_REF = "perf gate"
GATE_SPEEDUP = 3.0
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_cache.json")

BATCH = 64
BUDGET = 1 << 23     # 8 MiB — covers the whole bench dataset (~3 MiB)


def _cold_dataset(n: int, *, latency_s: float = 1.2e-3) -> Dataset:
    """Seek-bound cold storage with its own cache disabled: every epoch
    re-pays the full latency bill unless OUR tier absorbs it."""
    rng = np.random.default_rng(0)
    items = [rng.integers(0, 255, (32, 32, 3), dtype=np.uint8)
             for _ in range(n)]
    storage = LatencyStorage(ArrayStorage(items), latency_s=latency_s,
                             bandwidth=2e9, cache_bytes=0)
    return Dataset(storage, transform=image_transform)


def _cold_vs_warm(ds, *, num_batches, repeats):
    """Best-of-N delivery rate, cold (epoch 0, tier filling) vs warm
    (epoch >= 1, tier prewarmed) at EQUAL (num_workers, prefetch_factor).
    Both sides run through ``measure_transfer_time``'s measurement-only
    tier override, so the A/B never pollutes a live tier and repeats are
    independent."""
    params = LoaderParams(num_workers=2, prefetch_factor=2,
                          fast_path=True, zero_copy=True)
    dl = DataLoader(ds, BATCH, params=params, shuffle=True, seed=0)
    dl.measure_transfer_time(4, epoch=0, to_device=False)      # warmup
    best = {"cold": 0.0, "warm": 0.0}
    split = {"cold": (0, 0), "warm": (0, 0)}
    for rep in range(repeats):
        for name, epoch in (("cold", 0), ("warm", 1 + rep)):
            st = dl.measure_transfer_time(num_batches, epoch=epoch,
                                          to_device=False,
                                          cache_budget_bytes=BUDGET)
            bps = st.batches / st.seconds
            if bps > best[name]:
                best[name] = bps
                split[name] = (st.cache_hits, st.cache_misses)
    return best, split


def _stream_epoch_digests(ds, *, budget, num_batches, epochs=2):
    """Sorted per-sample digests of each LIVE-STREAM epoch (order-free).
    The stream is the path the tier actually serves, so this is the
    end-to-end identity check: admitted bytes == delivered bytes."""
    params = LoaderParams(num_workers=1, fast_path=True, locality_chunk=16,
                          cache_budget_bytes=budget)
    dl = DataLoader(ds, BATCH, params=params, shuffle=True, seed=0)
    s = dl.stream(to_device=False)
    per_epoch = []
    try:
        for _ in range(epochs):
            digests = []
            for _ in range(num_batches):
                batch = next(s)
                for row in np.asarray(batch["image"]):
                    digests.append(hashlib.sha1(row.tobytes()).hexdigest())
            per_epoch.append(sorted(digests))
    finally:
        s.close()
    return per_epoch, dl.io_counters()


def run(quick: bool = False):
    n = 1024 if quick else 2048
    num_batches = n // BATCH
    repeats = 2 if quick else 3
    ds = _cold_dataset(n)

    # --- correctness rider: byte-identical multiset, cache-on vs off ------
    cached, io = _stream_epoch_digests(ds, budget=BUDGET,
                                       num_batches=num_batches)
    uncached, _ = _stream_epoch_digests(ds, budget=0,
                                        num_batches=num_batches)
    for e in range(len(cached)):
        assert cached[e] == uncached[e], \
            f"cached epoch {e} is not the uncached epoch's sample multiset"
    assert io["cache_tier_hits"] > 0, "live stream never hit the tier"

    # --- the A/B gate ------------------------------------------------------
    best, split = _cold_vs_warm(ds, num_batches=num_batches,
                                repeats=repeats)
    speedup = best["warm"] / best["cold"]

    # --- rider: the hit/miss split is exact on both sides ------------------
    assert split["cold"] == (0, n), \
        f"cold epoch split {split['cold']} != (0, {n})"
    assert split["warm"] == (n, 0), \
        f"warm epoch split {split['warm']} != ({n}, 0)"

    # --- the DPT fourth axis resolves: real evaluator ----------------------
    dl = DataLoader(ds, BATCH, params=LoaderParams(fast_path=True),
                    shuffle=True, seed=0)
    cfg = DPTConfig(num_cpu_cores=2, num_devices=2, min_prefetch=1,
                    max_prefetch=2, num_batches=min(8, num_batches),
                    epoch=1, cache_budgets=(0, BUDGET))
    pick = tune(evaluator=LoaderEvaluator(dl, to_device=False),
                strategy="grid", config=cfg, measure_default=False)
    assert pick.cache_budget_bytes == BUDGET, \
        f"DPT grid picked budget {pick.cache_budget_bytes}, not {BUDGET}"

    # --- ... and the simulator prices the knob the same way ---------------
    sp = StorageProfile(num_items=10_000, item_bytes=1e5,
                        decoded_item_bytes=4e5, io_latency_s=5e-3,
                        seek_congestion=0.2, storage_bw=80e6,
                        decode_cpu_s_fixed=100e-6,
                        decode_cpu_s_per_byte=2e-9)
    mp = MachineProfile(host_ram=8e9, page_cache_eff=0.2,
                        worker_overhead_bytes=0.2e9)
    sim_cfg = DPTConfig(num_cpu_cores=4, num_devices=2, max_prefetch=2,
                        num_batches=8, epoch=1,
                        cache_budgets=(0, int(1e9)))
    sim_pick = tune(evaluator=SimulatorEvaluator(LoaderSimulator(sp, mp),
                                                 batch_size=32),
                    strategy="grid", config=sim_cfg,
                    measure_default=False)
    assert sim_pick.cache_budget_bytes == int(1e9), \
        "simulator grid kept budget 0 on the RAM-tight warm profile"

    rows = [{"epoch": "cold", "workers": 2, "prefetch": 2,
             "bps": round(best["cold"], 1),
             "hits": split["cold"][0], "misses": split["cold"][1]},
            {"epoch": "warm", "workers": 2, "prefetch": 2,
             "bps": round(best["warm"], 1),
             "hits": split["warm"][0], "misses": split["warm"][1],
             "speedup_x": round(speedup, 2)}]

    payload = {
        "bench": "cache",
        "gate": {"profile": "cold_cache_latency",
                 "budget_bytes": BUDGET,
                 "required_speedup_x": GATE_SPEEDUP,
                 "measured_speedup_x": round(speedup, 2),
                 "passed": speedup >= GATE_SPEEDUP,
                 "byte_identical_multiset": True,
                 "warm_split_exact": True,
                 "dpt_pick": {"nworker": pick.nworker,
                              "nprefetch": pick.nprefetch,
                              "cache_budget_bytes":
                              pick.cache_budget_bytes},
                 "sim_pick": {"nworker": sim_pick.nworker,
                              "nprefetch": sim_pick.nprefetch,
                              "cache_budget_bytes":
                              sim_pick.cache_budget_bytes}},
        "rows": rows,
        "host": {"platform": platform.platform(),
                 "python": sys.version.split()[0],
                 "numpy": np.__version__},
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")

    # honest 3x gate in the JSON; the hard failure floor is overridable so
    # noisy shared CI runners don't red-flag PRs on timing variance
    fail_below = float(os.environ.get("CACHE_GATE_MIN", GATE_SPEEDUP))
    if speedup < fail_below:
        raise RuntimeError(
            f"cache gate FAILED: {speedup:.2f}x < {fail_below}x warm-vs-"
            f"cold on the cold-cache profile (see {ROOT_JSON})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick="--quick" in sys.argv)))
