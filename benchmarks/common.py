"""Shared helpers for the benchmark harness.

Every bench module exposes ``run(quick=False) -> list[dict]`` and a
``TITLE`` / ``PAPER_REF`` pair; ``benchmarks.run`` drives them all, prints
aligned tables + a machine-readable CSV line per row, and archives the rows
under artifacts/bench/<name>.json.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def save_rows(name: str, rows: List[Dict]) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


def fmt_table(rows: List[Dict], cols: List[str] | None = None) -> str:
    if not rows:
        return "(no rows)"
    cols = cols or list(rows[0].keys())
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols}
    head = "  ".join(c.ljust(widths[c]) for c in cols)
    sep = "  ".join("-" * widths[c] for c in cols)
    body = "\n".join(
        "  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols)
        for r in rows)
    return f"{head}\n{sep}\n{body}"


def _fmt(v) -> str:
    if v is None:
        return "N/A"
    if isinstance(v, float):
        if v != v or v in (float("inf"), float("-inf")):
            return "N/A"
        if abs(v) >= 1000 or (abs(v) < 0.01 and v != 0):
            return f"{v:.3g}"
        return f"{v:.3f}"
    return str(v)


def csv_lines(name: str, rows: List[Dict]) -> List[str]:
    """name,key=value,... one line per row (greppable)."""
    out = []
    for r in rows:
        kv = ",".join(f"{k}={_fmt(v)}" for k, v in r.items())
        out.append(f"{name},{kv}")
    return out


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
