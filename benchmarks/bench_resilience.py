"""Resilience gate for the fault-tolerant data plane (DESIGN.md §10).

Three scenarios, one JSON verdict:

* **goodput under faults** — the SAME epoch is run fault-free and through
  a storm of 10% seeded transient read faults plus a mid-epoch brownout
  window (every miss fails while the storage's access clock is inside
  it).  Retries + backoff must ride both out with ZERO quarantined
  samples, a byte-identical delivered multiset, and goodput (host
  batches/sec) >= ``GATE_GOODPUT`` of the fault-free run;
* **corrupt quarantine exactness** — permanently corrupt items under
  ``on_bad_sample="skip"`` cost exactly themselves: the delivered epoch
  is the permutation minus the quarantine, nothing else lost, nothing
  duplicated, and the quarantine names exactly the corrupt set;
* **worker-crash containment** — a process-pool worker is SIGKILL'd
  mid-epoch; the per-worker-pipe transport must finish the epoch with
  exact coverage and at least one recorded resubmit, instead of hanging
  on the corpse (the ``multiprocessing.Pool`` failure mode).

Results land in ``artifacts/bench/resilience.json`` plus
``BENCH_resilience.json`` at the repo root (uploaded as a CI artifact),
mirroring the fastpath/locality/cache/straggler/fleet gates.  The hard
failure floor is overridable via ``RESILIENCE_GATE_MIN`` for noisy
shared runners.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import signal
import sys
import time

import numpy as np

from repro.data import DataLoader, LoaderParams, ShardedSampler
from repro.data.dataset import Dataset
from repro.data.faults import FaultyStorage, StorageFaultSpec
from repro.data.storage import ArrayStorage, LatencyStorage
from repro.data.worker_pool import ProcessWorkerPool

TITLE = "Fault-tolerant data plane gate (goodput under 10% faults + brownout)"
PAPER_REF = "perf gate"
GATE_GOODPUT = 0.5          # faulty goodput >= 50% of fault-free
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_resilience.json")

# fault storm calibration: 10% transient faults re-key by attempt (retries
# clear deterministically) and the brownout window sits mid-epoch in
# access-clock units — retries advance the clock, so sustained traffic
# heals it.  latency_s keeps the fault-free epoch comfortably nonzero so
# the goodput ratio measures recovery overhead, not harness noise.
N_ITEMS = 512
BATCH = 4
LATENCY_S = 4e-4
FAULT_RATE = 0.10
BROWNOUT = (60, 80)
RETRY = dict(retry_attempts=8, retry_backoff_s=2e-4, retry_deadline_s=5.0,
             on_bad_sample="skip")


def _ident(a):
    # module-level (picklable) transform for the process-pool scenario
    return {"x": a}


def _index_items(n):
    return [np.full((4,), i, np.int32) for i in range(n)]


def _storm_dataset(n: int = N_ITEMS, *, faults: bool) -> Dataset:
    storage = LatencyStorage(
        ArrayStorage(_index_items(n)), latency_s=LATENCY_S, bandwidth=2e9,
        cache_bytes=0, fault_rate=FAULT_RATE if faults else 0.0,
        fault_seed=17, brownout=BROWNOUT if faults else None)
    return Dataset(storage, transform=lambda a: {"x": a})


def _loader(ds: Dataset) -> DataLoader:
    return DataLoader(ds, BATCH, params=LoaderParams(
        num_workers=2, prefetch_factor=2, **RETRY), shuffle=True, seed=0)


def _epoch(dl: DataLoader):
    """One timed epoch: (seconds, sorted per-sample sha1 digests)."""
    digests = []
    t0 = time.perf_counter()
    for batch in dl.host_batches(epoch=0, num_batches=N_ITEMS // BATCH):
        for row in np.asarray(batch["x"]):
            digests.append(hashlib.sha1(row.tobytes()).hexdigest())
    return time.perf_counter() - t0, sorted(digests)


def goodput_scenario(repeats: int):
    """Min-of-N epoch wall time, fault-free vs through the storm.  Fresh
    storage per repeat: the access clock and attempt keys are stateful,
    so a reused storage would dodge its own brownout the second time."""
    t_clean, t_fault = float("inf"), float("inf")
    digests_clean = digests_fault = None
    faults_seen = retries_seen = 0
    for _ in range(repeats):
        dl = _loader(_storm_dataset(faults=False))
        dt, digests_clean = _epoch(dl)
        t_clean = min(t_clean, dt)

        ds = _storm_dataset(faults=True)
        dl = _loader(ds)
        dt, digests_fault = _epoch(dl)
        t_fault = min(t_fault, dt)
        assert ds.storage.faults_injected > 0, "storm injected nothing"
        assert len(dl.quarantine) == 0, \
            "transient faults must never quarantine"
        faults_seen = ds.storage.faults_injected
        retries_seen = dl.fault_stats.read_retries
    assert digests_fault == digests_clean, \
        "fault recovery changed the delivered sample multiset"
    return t_clean, t_fault, faults_seen, retries_seen


def corrupt_scenario():
    n, bad = 256, (7, 63, 100, 199, 255)
    ds = Dataset(FaultyStorage(ArrayStorage(_index_items(n)),
                               StorageFaultSpec(corrupt_items=bad)),
                 transform=lambda a: {"x": a})
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=2, **RETRY),
                    shuffle=True, seed=0)
    flat = sorted(int(i) for b in dl.host_batches(epoch=0)
                  for i in np.asarray(b["x"])[:, 0])
    assert flat == [i for i in range(n) if i not in bad], \
        "skip mode lost or duplicated a non-quarantined sample"
    assert sorted(dl.quarantine.ids().tolist()) == list(bad), \
        "quarantine does not name exactly the corrupt set"
    return {"n": n, "corrupt": len(bad),
            "quarantined": int(dl.io_counters()["quarantined"])}


def sigkill_scenario():
    n, gb = 192, 8
    ds = Dataset(ArrayStorage(_index_items(n)), transform=_ident)
    idx = ShardedSampler(n, gb, shuffle=False, seed=0).epoch_iter(0)
    pool = ProcessWorkerPool(ds, idx, num_workers=2, prefetch_factor=2,
                             ordered=True)
    t0 = time.perf_counter()
    it = iter(pool)
    got = [next(it)]
    os.kill(sorted(pool._worker_pids)[0], signal.SIGKILL)
    got.extend(it)
    dt = time.perf_counter() - t0
    flat = sorted(int(i) for b in got for i in np.asarray(b["x"])[:, 0])
    assert flat == list(range(n)), \
        "worker crash lost or duplicated a batch"
    assert pool.resubmits >= 1, "crash recovery recorded no resubmit"
    return {"batches": len(got), "resubmits": pool.resubmits,
            "epoch_s": round(dt, 3)}


def run(quick: bool = False):
    repeats = 2 if quick else 3
    bpe = N_ITEMS // BATCH

    t_clean, t_fault, faults, retries = goodput_scenario(repeats)
    ratio = t_clean / t_fault
    corrupt = corrupt_scenario()
    crash = sigkill_scenario()

    rows = [{"config": "fault_free", "epoch_s": round(t_clean, 3),
             "bps": round(bpe / t_clean, 1), "faults": 0},
            {"config": "fault_storm", "epoch_s": round(t_fault, 3),
             "bps": round(bpe / t_fault, 1), "faults": faults,
             "retries": retries, "goodput_ratio": round(ratio, 2)},
            {"config": "corrupt_skip", **corrupt},
            {"config": "sigkill_worker", **crash}]

    payload = {
        "bench": "resilience",
        "gate": {"profile": f"{FAULT_RATE:.0%}_transient+brownout",
                 "batch": BATCH,
                 "required_goodput_ratio": GATE_GOODPUT,
                 "measured_goodput_ratio": round(ratio, 2),
                 "passed": ratio >= GATE_GOODPUT,
                 "byte_identical_multiset": True,
                 "zero_quarantined_under_storm": True,
                 "corrupt_quarantine_exact": True,
                 "sigkill_resubmits": crash["resubmits"]},
        "rows": rows,
        "host": {"platform": platform.platform(),
                 "python": sys.version.split()[0],
                 "numpy": np.__version__},
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")

    fail_below = float(os.environ.get("RESILIENCE_GATE_MIN", GATE_GOODPUT))
    if ratio < fail_below:
        raise RuntimeError(
            f"resilience gate FAILED: goodput ratio {ratio:.2f} < "
            f"{fail_below} through the fault storm (see {ROOT_JSON})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick="--quick" in sys.argv)))
