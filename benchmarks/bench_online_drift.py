"""Online retuning after a mid-run storage slowdown (virtual time).

Scenario (warm-epoch regime, where the paper's own optima are
memory-coupled): a host is grid-tuned while healthy, then a co-tenant
moves in mid-run — disk bandwidth /4, request latency x6, and host RAM
cut 64GB -> 16GB.  The RAM loss is what moves the optimum: worker
processes + prefetch buffers now compete with the page cache, and the
stale worker count overflows outright.  Compare, on the degraded host:

* ``stale``   — keep running with the healthy-storage optimum (it
  overflows: stale_s is inf);
* ``online``  — the OnlineTuner's bounded hillclimb from the stale
  optimum, including the infeasible-start escape walk (what actually runs
  against a live loader, few measurements);
* ``scratch`` — a from-scratch Algorithm 1 grid retune (the full-cost
  reference the acceptance criterion is measured against).

The headline column is ``vs_scratch``: online-retuned throughput as a
fraction of from-scratch-retuned throughput (target: >= 0.90), bought for
``cells`` measurements instead of the grid's full sweep.
"""
import dataclasses
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import fmt_table, save_rows
from repro.core import DPTConfig, LoaderSimulator, MachineProfile, \
    MemoryOverflow, SimulatorEvaluator
from repro.core.cluster import degraded_storage
from repro.data.storage import cifar10_profile, coco_profile
from repro.tuning import tune

TITLE = "Online retune vs from-scratch retune after storage drift"
PAPER_REF = "beyond paper (conclusion's cloud-drift remark, mechanized)"

MACHINE = MachineProfile()
CFG = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=8,
                num_batches=32, epoch=1)
DEGRADED_MACHINE = dataclasses.replace(MACHINE, host_ram=16e9)


def _ev(profile, batch, machine=MACHINE):
    return SimulatorEvaluator(LoaderSimulator(profile, machine),
                              batch_size=batch)


def run(quick: bool = False):
    cases = [("cifar10 b32", cifar10_profile(), 32)]
    if not quick:
        cases += [("coco160 b32", coco_profile(160), 32),
                  ("coco320 b16", coco_profile(320), 16)]
    rows = []
    for name, healthy, batch in cases:
        degraded = degraded_storage(healthy, bw_scale=0.25,
                                    latency_scale=6.0)
        base = tune(evaluator=_ev(healthy, batch), strategy="grid",
                    config=CFG, measure_default=False)

        stale_ev = _ev(degraded, batch, DEGRADED_MACHINE)
        try:
            stale_s = stale_ev(base.nworker, base.nprefetch,
                               num_batches=CFG.num_batches).seconds
        except MemoryOverflow:
            stale_s = float("inf")

        online_ev = _ev(degraded, batch, DEGRADED_MACHINE)
        online = tune(evaluator=online_ev, strategy="hillclimb", config=CFG,
                      start=(base.nworker, base.nprefetch), max_steps=12)

        scratch_ev = _ev(degraded, batch, DEGRADED_MACHINE)
        scratch = tune(evaluator=scratch_ev, strategy="grid", config=CFG,
                       measure_default=False)

        rows.append({
            "profile": name,
            "healthy_opt": f"({base.nworker},{base.nprefetch})",
            "online_opt": f"({online.nworker},{online.nprefetch})",
            "scratch_opt": f"({scratch.nworker},{scratch.nprefetch})",
            # None (rendered N/A, valid JSON) when the stale config
            # overflows outright — the 100%-recovery case
            "stale_s": stale_s if math.isfinite(stale_s) else None,
            "online_s": online.optimal_time,
            "scratch_s": scratch.optimal_time,
            "vs_scratch": scratch.optimal_time / online.optimal_time,
            "recovered_pct": (100.0 * (stale_s - online.optimal_time)
                              / stale_s
                              if math.isfinite(stale_s) and stale_s > 0
                              else None),
            "cells": online_ev.calls,
            "grid_cells": scratch_ev.calls,
        })
    return rows


if __name__ == "__main__":
    rows = run(quick="--quick" in sys.argv)
    print(TITLE)
    print(fmt_table(rows))
    save_rows("online_drift", rows)
    worst = min(r["vs_scratch"] for r in rows)
    print(f"\nworst online-vs-scratch throughput ratio: {worst:.3f} "
          f"(acceptance target >= 0.90)")
