"""Benchmark harness driver — one module per paper table/figure plus the
beyond-paper studies and the roofline table.

    PYTHONPATH=src python -m benchmarks.run            # full
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized
    PYTHONPATH=src python -m benchmarks.run --only grid_cifar,prefetch

Prints one aligned table per bench, then a greppable CSV section
(``name,key=value,...``), and archives rows under artifacts/bench/.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

from benchmarks.common import csv_lines, fmt_table, save_rows

BENCHES = [
    # (name, module, paper table/figure)
    ("fastpath", "benchmarks.bench_fastpath", "perf gate"),
    ("locality", "benchmarks.bench_locality", "perf gate"),
    ("cache", "benchmarks.bench_cache", "perf gate"),
    ("straggler", "benchmarks.bench_straggler", "perf gate"),
    ("resilience", "benchmarks.bench_resilience", "perf gate"),
    ("grid_cifar", "benchmarks.bench_grid_cifar", "Fig 2a/2b/4"),
    ("prefetch", "benchmarks.bench_prefetch", "Fig 3"),
    ("coco_resolution", "benchmarks.bench_coco_resolution", "Table 1a-1d"),
    ("loader_wallclock", "benchmarks.bench_loader_wallclock", "real machinery"),
    ("multihost", "benchmarks.bench_multihost", "beyond-paper"),
    ("fleet", "benchmarks.bench_fleet", "beyond-paper"),
    ("elastic", "benchmarks.bench_elastic", "beyond-paper"),
    ("goodput", "benchmarks.bench_goodput", "beyond-paper"),
    ("search_cost", "benchmarks.bench_search_cost", "beyond-paper"),
    ("online_drift", "benchmarks.bench_online_drift", "beyond-paper"),
    ("roofline_table", "benchmarks.roofline_table", "§Roofline"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()

    only = set(args.only.split(",")) if args.only else None
    all_csv: list[str] = []
    failures = 0
    for name, modname, ref in BENCHES:
        if only and name not in only:
            continue
        mod = importlib.import_module(modname)
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failures += 1
            print(f"\n== {name} ({ref}) FAILED: {type(e).__name__}: {e}",
                  flush=True)
            continue
        dt = time.perf_counter() - t0
        save_rows(name, rows)
        print(f"\n== {getattr(mod, 'TITLE', name)} ({ref}) "
              f"[{dt:.1f}s, {len(rows)} rows] ==", flush=True)
        print(fmt_table(rows))
        all_csv.extend(csv_lines(name, rows))

    print("\n== CSV ==")
    for line in all_csv:
        print(line)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
