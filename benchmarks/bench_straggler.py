"""A/B gate for dual-lane slow-sample isolation (DESIGN.md §9).

Ordered delivery has a head-of-line pathology no (workers, prefetch,
locality, cache) point fixes: one rare slow decode parks every finished
batch behind it in the reorder buffer.  This bench plants a deterministic
heavy tail (3% of items cost 100x the base latency — corrupt-JPEG-sized
stragglers) in a ``LatencyStorage`` dataset and runs the SAME warm-tracker
epoch through the thread pool with the slow lane off vs on, at equal
(num_workers, prefetch_factor).  Gate: the dual-lane config delivers
>= 2x host batches/sec, with correctness riders:

* the dual-lane epoch's sample multiset is byte-identical to the
  single-lane epoch's (the lane changes WHEN work starts, never what
  arrives or in which order);
* an equal-threads baseline (all lane workers folded into the fast pool)
  is recorded alongside — the win is isolation, not extra parallelism;
* a DPT grid over (workers, prefetch, slow_lanes) on the simulator's
  heavy-tailed decode profile picks a nonzero lane width, and zero on the
  uniform profile (the fifth axis resolves, and only where it should);
* the serving rider: a ``BatchingFrontend`` with ``slow_lane=True``
  routes predicted-expensive request groups to the slow thread.

Results land in ``artifacts/bench/straggler.json`` plus
``BENCH_straggler.json`` at the repo root (uploaded as a CI artifact),
mirroring the fastpath/locality/cache/fleet gates.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import sys
import time

import numpy as np

from repro.data import DataLoader, LoaderParams
from repro.data.dataset import Dataset
from repro.data.storage import ArrayStorage, LatencyStorage

TITLE = "Dual-lane straggler isolation A/B (heavy-tail host batches/sec)"
PAPER_REF = "perf gate"
GATE_SPEEDUP = 2.0
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_straggler.json")

# calibrated straggler regime: rare (3%) and huge (100x) — the shape where
# ordered delivery stalls hardest; tail cost scales with latency_s so the
# whole bench stays sub-second per epoch
N_ITEMS = 512
BATCH = 4
LATENCY_S = 2e-4
TAIL = dict(tail_fraction=0.03, tail_mult=100.0, tail_seed=3)
LANE_WORKERS = 3
LOOKAHEAD = 32


def _tail_dataset(n: int = N_ITEMS) -> Dataset:
    items = [np.full((4,), i, np.float32) for i in range(n)]
    storage = LatencyStorage(ArrayStorage(items), latency_s=LATENCY_S,
                             bandwidth=2e9, cache_bytes=0, **TAIL)
    return Dataset(storage, transform=lambda a: {"x": a})


def _params(lane: int, *, workers: int = 2) -> LoaderParams:
    return LoaderParams(num_workers=workers, prefetch_factor=1,
                        zero_copy=True, ordered=True,
                        slow_lane_workers=lane,
                        slow_lane_lookahead=LOOKAHEAD)


def _epoch_seconds(dl: DataLoader, *, epochs_warm: int = 2,
                   repeats: int = 3) -> float:
    """Min-of-N wall time for one warm-tracker epoch.  The warm epochs
    teach the cost tracker where the stragglers are — a cold tracker
    routes nothing, so measuring epoch 0 would understate the win."""
    bpe = N_ITEMS // BATCH
    for e in range(epochs_warm):
        for _ in dl.host_batches(epoch=e, num_batches=bpe):
            pass
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in dl.host_batches(epoch=epochs_warm, num_batches=bpe):
            pass
        best = min(best, time.perf_counter() - t0)
    return best


def _epoch_digests(dl: DataLoader, epoch: int) -> list:
    """Sorted per-sample digests of one delivered epoch (order-free)."""
    digests = []
    for batch in dl.host_batches(epoch=epoch, num_batches=N_ITEMS // BATCH):
        for row in np.asarray(batch["x"]):
            digests.append(hashlib.sha1(row.tobytes()).hexdigest())
    return sorted(digests)


# --------------------------------------------------------------------------
# DPT rider: the fifth axis resolves on the simulator's straggler profile
# --------------------------------------------------------------------------
def dpt_lane_pick(heavy: bool):
    import dataclasses

    from repro.core.dpt import DPTConfig
    from repro.core.evaluators import SimulatorEvaluator
    from repro.core.simulator import LoaderSimulator, MachineProfile
    from repro.data.storage import cifar10_profile
    from repro.tuning import tune

    sp = dataclasses.replace(cifar10_profile(), decode_cpu_s_fixed=1e-3,
                             vectorized_decode_fixed_s=None)
    if heavy:
        sp = sp.with_heavy_tail(fraction=0.03, mult=100.0)
    sim = LoaderSimulator(sp, MachineProfile(
        physical_cores=8, logical_cores=8, reserved_cores=0, num_devices=2))
    cfg = DPTConfig(num_cpu_cores=8, num_devices=2, min_prefetch=1,
                    max_prefetch=2, num_batches=64, slow_lanes=(0, 1, 2, 3))
    return tune(evaluator=SimulatorEvaluator(sim, batch_size=4),
                strategy="grid", config=cfg, measure_default=False)


# --------------------------------------------------------------------------
# serving rider: expensive request groups take the slow thread
# --------------------------------------------------------------------------
class _SkewedEngine:
    """Duck-typed ServeEngine: one request shape is 20x the other."""
    max_batch = 4

    def generate(self, prompts, max_new):
        time.sleep(0.02 if max_new >= 64 else 0.001)

        class R:
            tokens = np.zeros((len(prompts), max_new), np.int32)
        return R()


def serving_rider() -> dict:
    from repro.serve.engine import BatchingFrontend
    fe = BatchingFrontend(_SkewedEngine(), max_wait_s=0.002,
                          slow_lane=True, slow_threshold=4.0)
    try:
        rng = np.random.default_rng(0)

        def burst(k, max_new):
            return [fe.submit(
                rng.integers(0, 100, (16,)).astype(np.int32), max_new)
                for _ in range(k)]

        for _ in range(4):              # warm the keyed tracker
            for r in burst(2, 4) + burst(2, 64):
                r.result.get(timeout=60)
        for r in burst(8, 64) + burst(8, 4):
            r.result.get(timeout=60)
        return {"slow_groups": fe.slow_groups,
                "fast_p99_s": round(fe.assembly_wait_p99(), 5),
                "slow_p99_s": round(fe.assembly_wait_p99(slow=True), 5),
                "routed": fe.slow_groups > 0}
    finally:
        fe.shutdown()


def run(quick: bool = False):
    repeats = 2 if quick else 3

    # --- correctness rider: byte-identical multiset, lane on vs off -------
    single = DataLoader(_tail_dataset(), BATCH, params=_params(0),
                        shuffle=True, seed=0)
    dual = DataLoader(_tail_dataset(), BATCH,
                      params=_params(LANE_WORKERS), shuffle=True, seed=0)
    assert _epoch_digests(single, 0) == _epoch_digests(dual, 0), \
        "dual-lane epoch is not the single-lane epoch's sample multiset"

    # --- the A/B gate: equal (workers, prefetch), lane off vs on ----------
    t_single = _epoch_seconds(single, repeats=repeats)
    t_dual = _epoch_seconds(dual, repeats=repeats)
    assert dual.cost_tracker.slow_batches > 0, \
        "warm tracker never routed a batch to the slow lane"
    speedup = t_single / t_dual

    # honesty baseline: same TOTAL thread count, no isolation — shows the
    # win is the early start, not just extra workers
    equal_threads = DataLoader(_tail_dataset(), BATCH,
                               params=_params(0, workers=2 + LANE_WORKERS),
                               shuffle=True, seed=0)
    t_equal = _epoch_seconds(equal_threads, repeats=repeats)

    bpe = N_ITEMS // BATCH
    rows = [{"config": "single_lane", "workers": 2, "lanes": 0,
             "epoch_s": round(t_single, 3),
             "bps": round(bpe / t_single, 1)},
            {"config": "equal_threads", "workers": 2 + LANE_WORKERS,
             "lanes": 0, "epoch_s": round(t_equal, 3),
             "bps": round(bpe / t_equal, 1)},
            {"config": "dual_lane", "workers": 2, "lanes": LANE_WORKERS,
             "epoch_s": round(t_dual, 3), "bps": round(bpe / t_dual, 1),
             "speedup_x": round(speedup, 2)}]

    # --- the DPT fifth axis resolves (and only on the straggler profile) --
    heavy_pick = dpt_lane_pick(heavy=True)
    uniform_pick = dpt_lane_pick(heavy=False)
    assert heavy_pick.slow_lane_workers > 0, \
        "DPT grid never priced a slow lane on the heavy-tailed profile"
    assert uniform_pick.slow_lane_workers == 0, \
        f"DPT grid spent {uniform_pick.slow_lane_workers} lane workers " \
        "on a uniform profile"

    # --- the serving rider ------------------------------------------------
    serve = serving_rider()
    assert serve["routed"], "frontend never routed an expensive group"

    payload = {
        "bench": "straggler",
        "gate": {"profile": "bimodal_3pct_100x", "batch": BATCH,
                 "required_speedup_x": GATE_SPEEDUP,
                 "measured_speedup_x": round(speedup, 2),
                 "passed": speedup >= GATE_SPEEDUP,
                 "byte_identical_multiset": True,
                 "slow_batches_routed": dual.cost_tracker.slow_batches,
                 "equal_threads_speedup_x": round(t_single / t_equal, 2),
                 "dpt_pick_heavy": {
                     "nworker": heavy_pick.nworker,
                     "nprefetch": heavy_pick.nprefetch,
                     "slow_lane_workers": heavy_pick.slow_lane_workers},
                 "dpt_pick_uniform": {
                     "slow_lane_workers": uniform_pick.slow_lane_workers}},
        "serving": serve,
        "rows": rows,
        "host": {"platform": platform.platform(),
                 "python": sys.version.split()[0],
                 "numpy": np.__version__},
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")

    # honest 2x gate in the JSON; the hard failure floor is overridable so
    # noisy shared CI runners don't red-flag PRs on timing variance
    fail_below = float(os.environ.get("STRAGGLER_GATE_MIN", GATE_SPEEDUP))
    if speedup < fail_below:
        raise RuntimeError(
            f"straggler gate FAILED: {speedup:.2f}x < {fail_below}x "
            f"dual-vs-single lane on the heavy-tail profile "
            f"(see {ROOT_JSON})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick="--quick" in sys.argv)))
