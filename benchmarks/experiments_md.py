"""Regenerate the machine-derived tables in EXPERIMENTS.md from the dry-run
artifacts (between the AUTOGEN markers; the §Perf narrative is hand-written).

    PYTHONPATH=src python -m benchmarks.experiments_md
"""
from __future__ import annotations

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRYRUN = os.path.join(ROOT, "artifacts", "dryrun")
BASELINE = os.path.join(ROOT, "artifacts", "dryrun_v0_paperfaithful")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")


def load(d, mesh):
    out = {}
    for p in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        with open(p) as f:
            a = json.load(f)
        out[(a["arch"], a["shape"])] = a
    return out


def fmt(v, nd=3):
    if v is None:
        return "—"
    return f"{v:.{nd}f}" if isinstance(v, float) else str(v)


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | chips | compile_s | peak GiB/dev | fits 16G | FLOPs/dev (body-once) | dominant |",
            "|---|---|---|---|---|---|---|---|---|"]
    for mesh in ("single", "multi"):
        for (arch, shape), a in sorted(load(DRYRUN, mesh).items()):
            if not a.get("ok"):
                rows.append(f"| {arch} | {shape} | {mesh} | — | — | — | — | — | "
                            f"FAIL: {a.get('error','?')[:40]} |")
                continue
            r = a["roofline"]
            rows.append(
                f"| {arch} | {shape} | {mesh} | {a['chips']} "
                f"| {fmt(a.get('compile_s'),1)} "
                f"| {a['memory']['peak_per_device']/2**30:.2f} "
                f"| {'yes' if a['fits_hbm_16g'] else '**NO**'} "
                f"| {a['cost']['flops']:.3g} | {r['dominant']} |")
    return "\n".join(rows)


def roofline_table() -> str:
    base = load(BASELINE, "single")
    cur = load(DRYRUN, "single")
    rows = ["| arch/shape | compute_s | memory_s | collective_s | dominant | "
            "step_s | MODEL/HLO flops | roofline frac | v0 step_s | v0→now |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for key, a in sorted(cur.items()):
        if not a.get("ok"):
            continue
        r = a["roofline"]
        b = base.get(key)
        b_step = b["roofline"]["step_s"] if (b and b.get("ok")) else None
        gain = f"{b_step / r['step_s']:.2f}×" if b_step else "—"
        rows.append(
            f"| {key[0]}/{key[1]} | {fmt(r['compute_s'])} | {fmt(r['memory_s'])} "
            f"| {fmt(r['collective_s'])} | {r['dominant']} | {fmt(r['step_s'])} "
            f"| {fmt(r['useful_flops_ratio'],3)} | {fmt(r['roofline_fraction'],4)} "
            f"| {fmt(b_step)} | {gain} |")
    return "\n".join(rows)


def replace_block(text: str, name: str, content: str) -> str:
    begin, end = f"<!-- AUTOGEN:{name} -->", f"<!-- /AUTOGEN:{name} -->"
    block = f"{begin}\n{content}\n{end}"
    if begin in text:
        return re.sub(re.escape(begin) + r".*?" + re.escape(end), block, text,
                      flags=re.S)
    return text + "\n" + block + "\n"


def main() -> None:
    with open(EXP) as f:
        text = f.read()
    text = replace_block(text, "dryrun", dryrun_table())
    text = replace_block(text, "roofline", roofline_table())
    with open(EXP, "w") as f:
        f.write(text)
    print(f"updated {EXP}")


if __name__ == "__main__":
    main()
