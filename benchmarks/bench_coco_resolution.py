"""Paper Tables 1a-1d — COCO-2017-unlabeled resized to 80/160/320/640 px,
batch 16..1024, 1st epoch (cold storage) vs 2nd+ epoch (page-cache warm).

Reports, per (batch, epoch, resolution):
  1a  optimal number of workers found by DPT,
  1b  full-epoch transfer seconds at the optimum,
  1c  time reduction % vs PyTorch defaults (negative = faster),
  1d  speedup (default / optimal).

Reproduced regimes: low-res -> optimum at full free cores (~10) and 1.2-1.4x
gains; >=320px cold epochs -> storage-bound optimum drops to ~5-6 workers;
640px -> gains ~1.0x (bandwidth wall); 640px @ batch 1024 -> N/A
(device-memory overflow, the paper's 12 GB GPU).
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.core import (DPT, DPTConfig, LoaderSimulator, MachineProfile,
                        MemoryOverflow, SimulatorEvaluator, default_params)
from repro.data.storage import coco_profile

TITLE = "COCO resolution x batch grid (optimal workers / epoch seconds / gain)"
PAPER_REF = "Table 1a-1d"

MACHINE = MachineProfile()
DEVICE_RAM = 12e9                      # paper: RTX 3080 Ti, 12 GB
RESOLUTIONS = (80, 160, 320, 640)
BATCHES = (16, 32, 64, 128, 256, 512, 1024)


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    batches = (16, 128, 1024) if quick else BATCHES
    for batch in batches:
        for epoch_label, epoch in (("1st", 0), ("2nd+", 1)):
            for res in RESOLUTIONS:
                sim = LoaderSimulator(coco_profile(res), MACHINE)
                ev = SimulatorEvaluator(sim, batch_size=batch,
                                        device_ram=DEVICE_RAM)
                cfg = DPTConfig(num_cpu_cores=12, num_devices=1,
                                max_prefetch=4 if quick else 8,
                                num_batches=16 if quick else 48, epoch=epoch)
                try:
                    r = DPT(ev, cfg).run(measure_default=False)
                    if not math.isfinite(r.optimal_time):
                        raise MemoryOverflow("all cells overflow")
                except MemoryOverflow:
                    rows.append({"batch": batch, "epoch": epoch_label,
                                 "res": res, "opt_workers": None,
                                 "epoch_s": None, "gain_pct": None,
                                 "speedup": None, "note": "N/A (overflow)"})
                    continue
                # full-epoch seconds (paper reports whole epochs)
                opt_s = ev.epoch_seconds(r.nworker, r.nprefetch, epoch=epoch)
                dw, dp = default_params(12)
                def_s = ev.epoch_seconds(dw, dp, epoch=epoch)
                rows.append({
                    "batch": batch, "epoch": epoch_label, "res": res,
                    "opt_workers": r.nworker, "opt_prefetch": r.nprefetch,
                    "epoch_s": round(opt_s, 2),
                    "default_s": round(def_s, 2),
                    "gain_pct": round(100.0 * (opt_s - def_s) / def_s, 2),
                    "speedup": round(def_s / opt_s, 3),
                })
    return rows


def main() -> None:
    from benchmarks.common import fmt_table, save_rows
    rows = run()
    print(f"== {TITLE} ({PAPER_REF}) ==")
    print(fmt_table(rows))
    print(save_rows("coco_resolution", rows))


if __name__ == "__main__":
    main()
