"""A/B gate for the zero-copy batch fast path (DESIGN.md §3).

Runs the SAME synthetic datasets through the legacy per-sample delivery
path (per-item ``Storage.read``, Python-loop transform, ``np.stack``
collation, fresh dict per batch) and the fast path (one ``read_batch``
gather, vectorized transform, slab-arena collation, slot tokens through the
queue), and reports host-side batches/sec for each.

Two dataset shapes bracket the paper's workloads:

* ``cifar_cpu_bound`` — 32x32x3 uint8 items, RAM-resident: the warm
  CPU-bound regime where interpreter overhead dominates and DPT's measured
  objective was mostly Python, not IO.  **The gate**: the fast path must
  deliver >= 3x the legacy batches/sec here, with byte-identical batches.
* ``coco_shaped`` — 160x160x3 items: heavier per-item decode, where the
  vectorized win is bounded by real memory bandwidth.

Results land in ``artifacts/bench/fastpath.json`` like every bench, plus
``BENCH_fastpath.json`` at the repo root so the perf trajectory across PRs
has a single well-known data point (CI uploads it as a workflow artifact).
"""
from __future__ import annotations

import json
import os
import platform
import sys

import numpy as np

from repro.data import DataLoader, LoaderParams, synthetic_image_dataset

TITLE = "Zero-copy fast path A/B (host batches/sec)"
PAPER_REF = "perf gate"
GATE_SPEEDUP = 3.0
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_fastpath.json")

LEGACY = LoaderParams(fast_path=False, zero_copy=False)
FAST = LoaderParams(fast_path=True, zero_copy=True)


def _ab_batches_per_s(ds, batch, legacy_params, fast_params, *,
                      num_batches, repeats=4):
    """Best-of-N host-side delivery rate for both paths, with the repeats
    INTERLEAVED legacy/fast/legacy/fast — on a shared box a load spike then
    degrades both sides instead of silently skewing the ratio."""
    mk = lambda p: DataLoader(ds, batch, params=p, shuffle=True, seed=0)
    legacy_dl, fast_dl = mk(legacy_params), mk(fast_params)
    for dl in (legacy_dl, fast_dl):    # warmup (slab spec, caches)
        dl.measure_transfer_time(min(8, num_batches), epoch=0,
                                 to_device=False)
    best = {"legacy": 0.0, "fast": 0.0}
    for rep in range(repeats):
        for name, dl in (("legacy", legacy_dl), ("fast", fast_dl)):
            st = dl.measure_transfer_time(num_batches, epoch=1 + rep,
                                          to_device=False)
            best[name] = max(best[name], st.batches / st.seconds)
    return best["legacy"], best["fast"]


def _assert_byte_identical(ds, batch, *, num_batches=4):
    """Legacy vs fast delivery of the same epoch must agree byte-for-byte.
    Bounded index iterators let the pools end (and their workers exit)
    naturally instead of being abandoned mid-epoch."""
    mk = lambda p: DataLoader(ds, batch, params=p, shuffle=False, seed=0)
    legacy = mk(LEGACY.replace(num_workers=0)).host_batches(
        epoch=0, num_batches=num_batches)
    fast = mk(FAST.replace(num_workers=2)).host_batches(
        epoch=0, num_batches=num_batches)
    for i, (a, b) in enumerate(zip(legacy, fast)):
        assert set(a) == set(b), f"field mismatch at batch {i}"
        for k in a:
            xa, xb = np.asarray(a[k]), np.asarray(b[k])
            assert xa.dtype == xb.dtype and xa.shape == xb.shape, (i, k)
            assert xa.tobytes() == xb.tobytes(), \
                f"batch {i} field {k!r} differs between paths"


def run(quick: bool = False):
    shapes = [
        # (profile, resolution, num_items, batch, worker counts)
        ("cifar_cpu_bound", 32, 2048 if quick else 4096, 64, (0, 2)),
        ("coco_shaped", 160, 128 if quick else 384, 16, (0, 2)),
    ]
    rows = []
    gate_speedup = None
    for profile, res, n, batch, worker_counts in shapes:
        ds = synthetic_image_dataset(n, res, seed=0)
        _assert_byte_identical(ds, batch)
        num_batches = n // batch
        for nw in worker_counts:
            legacy, fast = _ab_batches_per_s(
                ds, batch, LEGACY.replace(num_workers=nw),
                FAST.replace(num_workers=nw),
                num_batches=num_batches, repeats=3 if quick else 5)
            speedup = fast / legacy
            rows.append({"profile": profile, "workers": nw,
                         "legacy_bps": round(legacy, 1),
                         "fast_bps": round(fast, 1),
                         "speedup_x": round(speedup, 2),
                         "byte_identical": True})
            if profile == "cifar_cpu_bound" and nw == 0:
                gate_speedup = speedup

    payload = {
        "bench": "fastpath",
        "gate": {"profile": "cifar_cpu_bound", "workers": 0,
                 "required_speedup_x": GATE_SPEEDUP,
                 "measured_speedup_x": round(gate_speedup, 2),
                 "passed": gate_speedup >= GATE_SPEEDUP},
        "rows": rows,
        "host": {"platform": platform.platform(),
                 "python": sys.version.split()[0],
                 "numpy": np.__version__},
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")

    # The JSON records the honest 3x gate; the hard failure threshold is
    # overridable so shared CI runners (noisy 2-vCPU boxes) use a looser
    # bound without red-flagging unrelated PRs on timing variance.
    fail_below = float(os.environ.get("FASTPATH_GATE_MIN", GATE_SPEEDUP))
    if gate_speedup < fail_below:
        raise RuntimeError(
            f"fast path gate FAILED: {gate_speedup:.2f}x < "
            f"{fail_below}x on cifar_cpu_bound (see {ROOT_JSON})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_table
    print(fmt_table(run(quick="--quick" in sys.argv)))
