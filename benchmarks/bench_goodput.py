"""Beyond-paper: goodput-mode tuning — the loader only needs to outpace the
model step, so tuning to max throughput (the paper's objective) wastes host
cores whenever the accelerator is the bottleneck.

Two views:
 1. step-time sweep on the COCO-320 profile: tuned-for-max workers vs the
    smallest worker count that still hides the loader behind the step
    (cores freed on every node of a 1000-host fleet);
 2. per-arch coupling: the train_4k dry-run step-time estimate (roofline
    step_s from artifacts/dryrun) sets the target; the per-host input
    demand (global_batch/hosts x seq tokens) sets the dataset profile.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List

from repro.core import (DPT, DPTConfig, LoaderSimulator, MachineProfile,
                        SimulatorEvaluator)
from repro.core.search import goodput_tune
from repro.data.storage import StorageProfile, coco_profile

TITLE = "Goodput-mode tuning (loader >= model, minimal host resources)"
PAPER_REF = "beyond-paper (DESIGN.md §2 goodput mode)"

MACHINE = MachineProfile()
DRYRUN = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def token_profile(seq_len: int, *, vocab_bytes: int = 4) -> StorageProfile:
    """Pre-tokenized LM shards: sequential reads, negligible decode."""
    item = seq_len * vocab_bytes
    return StorageProfile(num_items=1_000_000, item_bytes=float(item),
                          decoded_item_bytes=float(2 * item),
                          io_latency_s=200e-6, seek_congestion=0.02,
                          storage_bw=1.2e9,
                          decode_cpu_s_fixed=30e-6,
                          decode_cpu_s_per_byte=0.5e-9)


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    cfg = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=4,
                    num_batches=16 if quick else 32, epoch=1)

    # --- view 1: step-time sweep, image regime ------------------------------
    ev = SimulatorEvaluator(LoaderSimulator(coco_profile(320), MACHINE),
                            batch_size=64)
    max_res = DPT(ev, cfg).run(measure_default=False)
    for step_s in (0.05, 0.2, 1.0):
        g = goodput_tune(ev, step_time_s=step_s,
                         num_batches=cfg.num_batches, config=cfg)
        rows.append({
            "view": "step-sweep", "profile": "coco320", "step_s": step_s,
            "max_workers": max_res.nworker, "goodput_workers": g.nworker,
            "cores_freed": max_res.nworker - g.nworker,
            "loader_s_per_batch": g.optimal_time / cfg.num_batches,
        })

    # --- view 2: per-arch coupling from the dry-run -------------------------
    hosts = 64                       # 256 chips, 4 local devices per host
    for arch in ("qwen2-0.5b", "yi-34b", "mixtral-8x22b"):
        path = os.path.join(DRYRUN, f"{arch}__train_4k__single.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            art = json.load(f)
        if not art.get("ok") or "roofline" not in art:
            continue
        step_s = art["roofline"]["step_s"]
        per_host_batch = max(1, 256 // hosts)
        prof = token_profile(4096)
        ev2 = SimulatorEvaluator(LoaderSimulator(prof, MACHINE),
                                 batch_size=per_host_batch)
        cfg2 = dataclasses.replace(cfg, num_devices=4)  # 4 local devices
        max2 = DPT(ev2, cfg2).run(measure_default=False)
        g2 = goodput_tune(ev2, step_time_s=step_s,
                          num_batches=cfg2.num_batches, config=cfg2)
        rows.append({
            "view": "per-arch", "profile": arch, "step_s": round(step_s, 3),
            "max_workers": max2.nworker, "goodput_workers": g2.nworker,
            "cores_freed": max2.nworker - g2.nworker,
            "loader_s_per_batch": g2.optimal_time / cfg2.num_batches,
        })
        # input-bound check: can the loader keep up at all?
        per_batch = g2.optimal_time / cfg2.num_batches
        rows[-1]["input_bound"] = bool(per_batch > step_s)
    return rows


def main() -> None:
    from benchmarks.common import fmt_table, save_rows
    rows = run()
    print(f"== {TITLE} ({PAPER_REF}) ==")
    print(fmt_table(rows))
    print(save_rows("goodput", rows))


if __name__ == "__main__":
    main()
