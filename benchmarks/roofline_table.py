"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape), single-pod mesh per the assignment: the three roofline
terms in seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness
ratio, roofline fraction, and peak HBM per device.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

TITLE = "Roofline terms per (arch x shape), single-pod 16x16"
PAPER_REF = "assignment §Roofline"

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh: str = "single") -> List[dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def run(quick: bool = False, mesh: str = "single") -> List[Dict]:
    rows: List[Dict] = []
    for art in load_cells(mesh):
        if not art.get("ok"):
            rows.append({"cell": f"{art['arch']}/{art['shape']}",
                         "error": art.get("error", "?")[:60]})
            continue
        r = art["roofline"]
        rows.append({
            "cell": f"{art['arch']}/{art['shape']}",
            "compute_s": round(r["compute_s"], 4),
            "memory_s": round(r["memory_s"], 4),
            "collective_s": round(r["collective_s"], 4),
            "dominant": r["dominant"],
            "step_s": round(r["step_s"], 4),
            "roofline_frac": round(r["roofline_fraction"], 4),
            "useful_flops": round(r["useful_flops_ratio"], 3),
            "peak_GiB": round(art["memory"]["peak_per_device"] / 2**30, 2),
            "fits16G": art["fits_hbm_16g"],
        })
    return rows


def main() -> None:
    from benchmarks.common import fmt_table, save_rows
    rows = run()
    print(f"== {TITLE} ({PAPER_REF}) ==")
    print(fmt_table(rows))
    print(save_rows("roofline_table", rows))


if __name__ == "__main__":
    main()
