"""Real wall-clock loader microbenchmark (no simulation).

Exercises the actual thread pool / prefetch / device_put machinery against
sleep-injected IO latency (sleep releases the GIL, so worker scaling is
real even on this 1-core container):

* worker scaling at fixed prefetch — latency hiding;
* prefetch-factor effect at fixed workers — pipeline fill;
* page-cache warm epoch — repeat reads hit the LatencyStorage cache;
* host->device stage (device_put double-buffer) on the CPU device.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.dataset import Dataset, image_transform
from repro.data.loader import DataLoader, LoaderParams
from repro.data.storage import ArrayStorage, LatencyStorage

TITLE = "Real loader wall-clock (threads + prefetch + device_put)"
PAPER_REF = "Fig 2a mechanism, real machinery"


def make_dataset(num_items: int = 512, item_kb: int = 48,
                 latency_s: float = 2e-3, cache: bool = False) -> Dataset:
    rng = np.random.default_rng(0)
    side = int(np.sqrt(item_kb * 1024 / 3))
    items = [rng.integers(0, 255, (side, side, 3), dtype=np.uint8)
             for _ in range(num_items)]
    inner = ArrayStorage(items)
    storage = LatencyStorage(inner, latency_s=latency_s, bandwidth=400e6,
                             cache_bytes=(1 << 30) if cache else 0)
    return Dataset(storage, transform=image_transform)


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    batch, nb = 32, (6 if quick else 10)

    # worker scaling (cold reads, sleep-bound)
    ds = make_dataset(num_items=512 if quick else 768)
    base = None
    for w in (0, 1, 2, 4, 8):
        dl = DataLoader(ds, batch, shuffle=False,
                        params=LoaderParams(num_workers=w, prefetch_factor=2))
        s = dl.measure_transfer_time(nb, epoch=0, to_device=False)
        base = base or s.seconds
        rows.append({"sweep": "workers", "workers": w, "prefetch": 2,
                     "seconds": round(s.seconds, 3),
                     "speedup_vs_w0": round(base / s.seconds, 2),
                     "MB_per_s": round(s.bytes_per_second / 1e6, 1)})

    # prefetch effect at fixed workers
    for j in (1, 2, 4):
        dl = DataLoader(ds, batch, shuffle=False,
                        params=LoaderParams(num_workers=4, prefetch_factor=j))
        s = dl.measure_transfer_time(nb, epoch=0, to_device=False)
        rows.append({"sweep": "prefetch", "workers": 4, "prefetch": j,
                     "seconds": round(s.seconds, 3),
                     "MB_per_s": round(s.bytes_per_second / 1e6, 1)})

    # warm epoch via the page cache
    ds_c = make_dataset(num_items=256, cache=True)
    dl = DataLoader(ds_c, batch, shuffle=False,
                    params=LoaderParams(num_workers=4, prefetch_factor=2))
    cold = dl.measure_transfer_time(nb, epoch=0, to_device=False)
    warm = dl.measure_transfer_time(nb, epoch=0, to_device=False)  # re-read
    rows.append({"sweep": "page-cache", "workers": 4, "prefetch": 2,
                 "seconds": round(warm.seconds, 3),
                 "speedup_vs_w0": round(cold.seconds / warm.seconds, 2)})

    # include the device stage (device_put onto the CPU device)
    dl = DataLoader(ds_c, batch, shuffle=False,
                    params=LoaderParams(num_workers=4, prefetch_factor=2,
                                        device_prefetch=2))
    s = dl.measure_transfer_time(nb, epoch=0, to_device=True)
    rows.append({"sweep": "to-device", "workers": 4, "prefetch": 2,
                 "seconds": round(s.seconds, 3),
                 "MB_per_s": round(s.bytes_per_second / 1e6, 1)})
    return rows


def main() -> None:
    from benchmarks.common import fmt_table, save_rows
    rows = run()
    print(f"== {TITLE} ({PAPER_REF}) ==")
    print(fmt_table(rows))
    print(save_rows("loader_wallclock", rows))


if __name__ == "__main__":
    main()
