"""Beyond-paper: search-strategy cost — the paper's exhaustive grid pays
O(N/G x P) full measurements per (machine, dataset) pair; on a 1000-node
fleet that cost recurs per machine class and per dataset.  Successive
halving and cost-model-warm-started hillclimb find the same optimum for a
fraction of the measurements.

Reported per (profile, strategy): measurements used, total measured seconds
(the tuning bill), found cell, regret vs the exhaustive-grid optimum.
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.core import (DPT, DPTConfig, LoaderSimulator, MachineProfile,
                        SimulatorEvaluator)
from repro.core.search import successive_halving, tuned_with_warmstart
from repro.data.storage import cifar10_profile, coco_profile

TITLE = "Tuning cost: grid vs successive-halving vs warmstart+hillclimb"
PAPER_REF = "beyond-paper (search.py)"

MACHINE = MachineProfile()

PROFILES = {
    "cifar10-warm": (cifar10_profile(), 32, 1),
    "coco80-cold": (coco_profile(80), 32, 0),
    "coco320-cold": (coco_profile(320), 64, 0),
    "coco640-warm": (coco_profile(640), 16, 1),
}


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    names = list(PROFILES)[:2] if quick else list(PROFILES)
    for name in names:
        storage, batch, epoch = PROFILES[name]
        cfg = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=8,
                        num_batches=16 if quick else 32, epoch=epoch)

        def fresh_ev():
            return SimulatorEvaluator(LoaderSimulator(storage, MACHINE),
                                      batch_size=batch)

        # exhaustive grid (Algorithm 1)
        ev = fresh_ev()
        grid = DPT(ev, cfg).run(measure_default=False)
        grid_calls, grid_best = ev.calls, grid.optimal_time
        bill_grid = sum(t.seconds for t in grid.trials
                        if math.isfinite(t.seconds))
        rows.append({"profile": name, "strategy": "grid(Alg1)",
                     "measurements": grid_calls, "tuning_bill_s": bill_grid,
                     "found": f"({grid.nworker},{grid.nprefetch})",
                     "regret_pct": 0.0})

        # successive halving
        ev = fresh_ev()
        sh = successive_halving(ev, config=cfg)
        # re-measure SH's pick at the full budget for a fair regret
        t_sh = ev(sh.nworker, sh.nprefetch, num_batches=cfg.num_batches,
                  epoch=epoch).seconds
        rows.append({"profile": name, "strategy": "succ-halving",
                     "measurements": ev.calls - 1,
                     "tuning_bill_s": sum(t.seconds for t in sh.trials
                                          if math.isfinite(t.seconds)),
                     "found": f"({sh.nworker},{sh.nprefetch})",
                     "regret_pct": 100 * (t_sh / grid_best - 1)})

        # cost-model warmstart + coordinate hillclimb
        ev = fresh_ev()
        hc = tuned_with_warmstart(ev, storage, MACHINE, batch_size=batch,
                                  config=cfg)
        t_hc = ev(hc.nworker, hc.nprefetch, num_batches=cfg.num_batches,
                  epoch=epoch).seconds
        rows.append({"profile": name, "strategy": "warmstart+hillclimb",
                     "measurements": ev.calls - 1,
                     "tuning_bill_s": sum(t.seconds for t in hc.trials
                                          if math.isfinite(t.seconds)),
                     "found": f"({hc.nworker},{hc.nprefetch})",
                     "regret_pct": 100 * (t_hc / grid_best - 1)})
    return rows


def main() -> None:
    from benchmarks.common import fmt_table, save_rows
    rows = run()
    print(f"== {TITLE} ({PAPER_REF}) ==")
    print(fmt_table(rows))
    print(save_rows("search_cost", rows))


if __name__ == "__main__":
    main()
