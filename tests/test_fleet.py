"""Fleet control plane: elastic resharding, coordinator decisions, and the
adaptive-budget / variance-aware-win satellites.

The coverage tests assert the reshard invariant EXACTLY (every index once,
as a multiset over everything every host delivered) — any lost sample
leaves a hole, any duplicate a repeat.  Randomized reshard-coverage
sweeps (the hand-enumerated case lists that used to sit here) moved to
test_properties.py; randomized fleet fault timelines live there too.
"""
import math

import numpy as np
import pytest

from conftest import (flat_indices as _flat_indices,
                      make_index_dataset as _index_dataset,
                      make_table_evaluator as _table_evaluator)

from repro.core.cluster import FleetEvent, FleetSchedule
from repro.core.dpt import DPTConfig, DPTResult, Trial
from repro.data import DataLoader, LoaderParams
from repro.data.sampler import SamplerState, ShardedSampler
from repro.tuning import (FleetConfig, FleetCoordinator, HostAgent,
                          OnlineTuner, OnlineTunerConfig, RetunePolicy,
                          adaptive_budget, uniform_consensus, welch_wins)


def test_sampler_reshard_validates():
    s = ShardedSampler(120, 12, host_index=0, host_count=3)
    with pytest.raises(ValueError, match="not divisible"):
        s.reshard(5, 0)
    with pytest.raises(ValueError, match="out of range"):
        s.reshard(3, 3)
    s.reshard(4, 2)
    assert (s.host_count, s.host_index, s.local_batch) == (4, 2, 3)


def test_sampler_checkpoint_round_trip_across_reshard():
    """Checkpoint at the barrier, reshard, keep going — a fresh sampler
    restored from the checkpoint with the NEW topology must produce the
    identical sequence (reshard state is topology, position is state)."""
    n, gb = 240, 12
    s = ShardedSampler(n, gb, shuffle=True, seed=4, host_index=1,
                       host_count=4)
    it = iter(s)
    for _ in range(7):
        next(it)
    saved = s.state.to_dict()
    s.reshard(3, 1)
    live = [next(it).tolist() for _ in range(6)]

    restored = ShardedSampler(n, gb, shuffle=True, seed=4, host_index=1,
                              host_count=3,
                              state=SamplerState.from_dict(saved))
    again = [next(iter(restored)) for _ in range(6)]
    assert live == [a.tolist() for a in again]


def test_sampler_state_absolute_round_trip():
    st = SamplerState(epoch=3, batch_offset=7)
    assert SamplerState.from_absolute(st.absolute(20), 20) == st


# --------------------------------------------------------------------------
# live-loader reshard: barrier + makeup, exact coverage
# --------------------------------------------------------------------------
def test_live_reshard_with_makeup_exact_coverage():
    """2-host fleet, host1 dies after 5 batches while host0 is at 8: host0
    takes over at the barrier, host1's undelivered slices [5, 8) arrive as
    makeup — and the epoch's index multiset is exactly covered."""
    n, gb = 240, 12
    mk = lambda h: DataLoader(
        _index_dataset(n), gb, shuffle=True, seed=3,
        params=LoaderParams(num_workers=2, prefetch_factor=2),
        host_index=h, host_count=2)
    h0, h1 = mk(0), mk(1)
    s0, s1 = h0.stream(to_device=False), h1.stream(to_device=False)
    delivered = []
    delivered += [next(s1) for _ in range(5)]        # host1 then dies
    delivered += [next(s0) for _ in range(8)]
    barrier = max(s0.position, s1.position)
    assert (s0.position, s1.position) == (8, 5)

    ref = ShardedSampler(n, gb, shuffle=True, seed=3, host_index=1,
                         host_count=2)
    makeup = [ref.local_indices(0, b) for b in range(5, barrier)]
    h0.reshard(1, 0, at_batch=barrier, makeup=makeup)
    while s0.position < n // gb:
        delivered.append(next(s0))
    s0.close()
    s1.close()
    assert _flat_indices(delivered) == list(range(n))
    assert s0.reshards == 1


def test_live_reshard_without_stream_remaps_sampler():
    dl = DataLoader(_index_dataset(48), 12, host_index=0, host_count=2)
    dl.reshard(3, 2)
    assert (dl.sampler.host_count, dl.sampler.host_index) == (3, 2)
    with pytest.raises(ValueError, match="live stream"):
        dl.reshard(2, 0, makeup=[np.array([1, 2])])


def test_device_prefetch_depth_hot_swap():
    """The device-side buffer depth retunes at the swap boundary (it used
    to be fixed at stream creation)."""
    dl = DataLoader(_index_dataset(512), 8, shuffle=False, seed=0,
                    params=LoaderParams(num_workers=2, prefetch_factor=2,
                                        device_prefetch=2))
    stream = dl.stream(to_device=True)
    got = [next(stream) for _ in range(3)]
    dl.apply_params(dl.params.replace(num_workers=1, device_prefetch=4))
    while stream.swaps == 0:
        got.append(next(stream))
    assert stream._prefetcher.depth == 4
    dl.apply_params(dl.params.replace(device_prefetch=1))
    while stream.swaps == 1:
        got.append(next(stream))
    assert stream._prefetcher.depth == 1
    # delivery stayed exact through both swaps
    assert _flat_indices(got) == list(range(len(got) * 8))
    stream.close()


# --------------------------------------------------------------------------
# FleetCoordinator: death, drift, join  (fleet_factory lives in conftest)
# --------------------------------------------------------------------------
def test_coordinator_death_reshards_with_exact_coverage(fleet_factory):
    n, gb = 480, 12
    fleet = fleet_factory(n, gb)
    clock, coord = fleet.clock, fleet.coord
    agents, streams = fleet.agents, fleet.streams
    delivered = {h: [] for h in range(3)}
    for rnd in range(12):
        clock[0] += 1.0
        for h in range(3):
            if h == 2 and rnd >= 7:
                continue             # host2 goes silent mid-run
            delivered[h].append(next(streams[h]))
            agents[h].observe(data_s=0.001, step_s=0.1)
        coord.poll()
    clock[0] += 10.0                 # silence outlives the timeout
    for h in (0, 1):
        agents[h].heartbeat()
    actions = coord.poll()
    reshard = next(a for a in actions if a["kind"] == "reshard")
    assert reshard["host"] == "host2"
    assert reshard["makeup_batches"] == reshard["barrier"] - 7
    assert reshard["plan"].feasible

    for h in (0, 1):
        while streams[h].position < n // gb:
            delivered[h].append(next(streams[h]))
        streams[h].close()
    streams[2].close()
    everything = [b for blist in delivered.values() for b in blist]
    assert _flat_indices(everything) == list(range(n))
    assert coord.reshards == 1
    assert "host2" not in coord.agents


def test_coordinator_correlated_deaths_one_reshard_exact_coverage(
        fleet_factory):
    """Two hosts dying in the same detection window (a rack failure) are
    handled as ONE reshard: neither dead host is treated as a survivor of
    the other's reshard, and no makeup share is parked on a corpse."""
    n, gb = 480, 12
    fleet = fleet_factory(n, gb, hosts=4, cooldown_steps=1000,
                          evaluator_fn=lambda i, j: 1.0)
    clock, coord = fleet.clock, fleet.coord
    agents, streams = fleet.agents, fleet.streams
    delivered = {h: [] for h in range(4)}
    for rnd in range(10):
        clock[0] += 1.0
        for h in range(4):
            if h >= 2 and rnd >= 6:
                continue             # hosts 2 AND 3 go silent together
            delivered[h].append(next(streams[h]))
            agents[h].observe(data_s=0.001, step_s=0.1)
        coord.poll()
    clock[0] += 10.0
    for h in (0, 1):
        agents[h].heartbeat()
    actions = coord.poll()
    reshards = [a for a in actions if a["kind"] == "reshard"]
    assert len(reshards) == 1
    assert sorted(reshards[0]["lost"]) == ["host2", "host3"]
    assert reshards[0]["hosts"] == 2
    assert reshards[0]["makeup_batches"] == 2 * (reshards[0]["barrier"] - 6)

    for h in (0, 1):
        while streams[h].position < n // gb:
            delivered[h].append(next(streams[h]))
    for s in streams:
        s.close()
    everything = [b for blist in delivered.values() for b in blist]
    assert _flat_indices(everything) == list(range(n))


def test_arena_respec_expected_leading_rejects_ragged_first_batch():
    """A ragged makeup chunk arriving first after a reshard must not pin
    the arena spec to the wrong local batch shape."""
    from repro.data.arena import SlabArena
    arena = SlabArena(4)
    assert arena.adopt({"x": np.zeros((4, 3))}) is not None   # spec @ 4
    arena.respec(expected_leading=6)
    assert arena.adopt({"x": np.zeros((4, 3))}) is None       # stale shape
    assert arena.adopt({"x": np.zeros((2, 3))}) is None       # ragged tail
    slot = arena.adopt({"x": np.zeros((6, 3))})               # the new spec
    assert slot is not None
    slot.release()
    assert arena.acquire() is not None


def test_coordinator_drift_pushes_uniform_params_to_all_hosts(
        fleet_factory):
    fleet = fleet_factory()
    clock, coord = fleet.clock, fleet.coord
    agents, streams = fleet.agents, fleet.streams
    # stalled fleet: data-wait dominates compute on every host
    for _ in range(6):
        clock[0] += 1.0
        for a in agents:
            a.observe(data_s=0.09, step_s=0.1)
    actions = coord.poll()
    consensus = next(a for a in actions if a["kind"] == "consensus")
    assert consensus["reason"] == "goodput-drift"
    assert consensus["applied"]
    assert consensus["params"] == (4, 1)     # argmin of 4/i + 0.1j
    for a in agents:
        assert a.loader.params.num_workers == 4
        assert a.loader.params.prefetch_factor == 1
    for s in streams:
        s.close()


def test_coordinator_straggler_triggers_consensus(fleet_factory):
    fleet = fleet_factory()
    clock, coord = fleet.clock, fleet.coord
    agents, streams = fleet.agents, fleet.streams
    for _ in range(6):
        clock[0] += 1.0
        for i, a in enumerate(agents):
            # host2 is 4x slower per step but data stays hidden: only the
            # straggler signal can catch this
            step = 0.4 if i == 2 else 0.1
            a.observe(data_s=0.001, step_s=step)
    actions = coord.poll()
    consensus = next(a for a in actions if a["kind"] == "consensus")
    assert consensus["reason"].startswith("straggler-divergence:host2")
    for s in streams:
        s.close()


def test_coordinator_join_expands_fleet_with_exact_coverage(fleet_factory):
    """3 -> 4 hosts mid-epoch: incumbents reshard at the barrier, the
    newcomer aligns to it and takes the last shard."""
    n, gb = 480, 12
    fleet = fleet_factory(n, gb)
    clock, coord = fleet.clock, fleet.coord
    agents, streams = fleet.agents, fleet.streams
    delivered = []
    for rnd in range(6):
        clock[0] += 1.0
        for h in range(3):
            delivered.append(next(streams[h]))
            agents[h].observe(data_s=0.001, step_s=0.1)

    dl_new = DataLoader(_index_dataset(n), gb, shuffle=True, seed=5,
                        params=LoaderParams(num_workers=1,
                                            prefetch_factor=2))
    newcomer = HostAgent("host3", dl_new,
                         evaluator=_table_evaluator(lambda i, j: 1.0))
    barrier = coord.join(newcomer)
    assert barrier >= 6
    assert dl_new.sampler.state.batch_offset == barrier
    assert (dl_new.sampler.host_count, dl_new.sampler.host_index) == (4, 3)

    streams.append(dl_new.stream(to_device=False))
    for s in streams:
        while s.position < n // gb:
            delivered.append(next(s))
        s.close()
    assert _flat_indices(delivered) == list(range(n))
    assert len(coord.agents) == 4


def test_coordinator_no_win_consensus_backs_off(fleet_factory):
    fleet = fleet_factory()
    coord, agents, streams = fleet.coord, fleet.agents, fleet.streams
    for a in agents:                 # flat objective: nothing to win
        a.evaluator = _table_evaluator(lambda i, j: 1.0)
    before = [a.loader.params for a in agents]
    coord.request_consensus(reason="forced")
    actions = coord.poll()
    consensus = next(a for a in actions if a["kind"] == "consensus")
    assert not consensus["applied"]
    assert [a.loader.params for a in agents] == before
    assert coord._backoff == 2
    for s in streams:
        s.close()


def test_fleet_schedule_fires_once_in_order():
    sched = FleetSchedule([FleetEvent(step=3, kind="degrade", host="h1",
                                      io_scale=4.0),
                           FleetEvent(step=3, kind="leave", host="h2")])
    sched.add(FleetEvent(step=5, kind="join", host="h3"))
    assert sched.at(0) == []
    fired = sched.at(3)
    assert [e.kind for e in fired] == ["degrade", "leave"]
    assert sched.at(3) == []         # events fire exactly once
    assert sched.pending == 1
    assert [e.kind for e in sched.at(5)] == ["join"]
    with pytest.raises(ValueError, match="unknown fleet event"):
        FleetEvent(step=0, kind="explode", host="h0")


def test_uniform_consensus_requires_universal_feasibility():
    ok = Trial(2, 1, 1.0)
    res_a = DPTResult(2, 1, 1.0, [ok, Trial(4, 1, 0.5)])
    res_b = DPTResult(2, 1, 2.0, [Trial(2, 1, 2.0),
                                  Trial(4, 1, math.inf, overflowed=True)])
    best, fleet_time = uniform_consensus([res_a, res_b])
    assert best == (2, 1)            # (4,1) is faster but overflows on b
    assert fleet_time == 2.0


# --------------------------------------------------------------------------
# makeup accounting regressions (found by the fault-injection matrix in
# test_properties.py): consumed-position vs makeup yields, and makeup
# surviving a later reshard / a recipient's death
# --------------------------------------------------------------------------
def test_consumed_position_not_inflated_by_makeup_yields():
    """A host that consumed makeup batches must not over-report its
    regular-batch position — one-observe-per-step counting loses samples
    the moment that host dies (its makeup window starts too late)."""
    n, gb = 240, 12
    dl = DataLoader(_index_dataset(n), gb, shuffle=True, seed=3,
                    params=LoaderParams(num_workers=1, prefetch_factor=1))
    agent = HostAgent("h0", dl, evaluator=_table_evaluator(lambda i, j: 1.0))
    stream = dl.stream(to_device=False)
    for _ in range(3):
        next(stream)
        agent.observe(data_s=0.0, step_s=0.1)
    assert agent.consumed_position() == 3
    # two makeup chunks arrive (another host died elsewhere)
    dl.add_makeup([np.array([7, 8]), np.array([9, 10])])
    for _ in range(4):                   # 2 makeup + 2 regular, any order
        next(stream)
        agent.observe(data_s=0.0, step_s=0.1)
    assert agent.consumed_position() == 5    # NOT 7: makeup doesn't count
    assert stream.position == 5
    stream.close()


def test_reshard_recovers_pulled_but_undelivered_makeup():
    """A reshard's discard boundary regenerates regular batches by
    rewinding the sampler — makeup the pool had pulled but not delivered
    must go back on the queue, not die with the pool."""
    n, gb = 240, 12
    dl = DataLoader(_index_dataset(n), gb, shuffle=True, seed=3,
                    params=LoaderParams(num_workers=2, prefetch_factor=2))
    stream = dl.stream(to_device=False)
    delivered = [next(stream) for _ in range(4)]
    makeup = [np.arange(12), np.arange(12, 24)]
    dl.add_makeup(makeup)
    # reshard lands immediately: the pool likely pulled the makeup already
    dl.reshard(2, 0, at_batch=stream.position)
    while stream.position < n // gb:
        delivered.append(next(stream))
    got = [x for b in delivered for x in np.asarray(b["x"])[:, 0].tolist()]
    # both makeup chunks arrived exactly once despite the discard
    for idx in range(24):
        assert got.count(idx) >= 1
    assert stream.reshards == 1
    stream.close()


def test_undelivered_makeup_counts_unconsumed_yields():
    """Makeup yielded into a device prefetcher is not CONSUMED: querying
    with the consumer's yield count must recover it (a dead host's
    prefetcher-held makeup is otherwise lost)."""
    n, gb = 120, 12
    dl = DataLoader(_index_dataset(n), gb, shuffle=True, seed=3,
                    params=LoaderParams(num_workers=1, prefetch_factor=1))
    stream = dl.stream(to_device=False)
    next(stream)
    chunks = [np.array([1, 2, 3]), np.array([4, 5])]
    dl.add_makeup(chunks)
    # drain until both makeup chunks have been YIELDED
    while stream.position < 4:
        next(stream)
    consumed_all = stream.yields
    # consumer kept up: nothing undelivered
    assert dl.undelivered_makeup(consumed_yields=consumed_all) == []
    # consumer died one yield behind (prefetcher held the last batch):
    # any makeup among the unconsumed suffix is recovered
    recovered = stream.undelivered_makeup(consumed_yields=1)
    assert sorted(np.concatenate(recovered).tolist()) == [1, 2, 3, 4, 5]
    stream.close()


def test_dead_hosts_undelivered_makeup_redistributed(fleet_factory):
    """Makeup dealt to a host that later dies is re-redistributed by the
    next reshard (no makeup parked on a corpse)."""
    n, gb = 480, 12
    fleet = fleet_factory(n, gb, hosts=3, cooldown_steps=1000)
    clock, coord = fleet.clock, fleet.coord
    agents, streams = fleet.agents, fleet.streams
    delivered = []
    # host2 dies first; its window becomes makeup on host0/host1
    for rnd in range(6):
        clock[0] += 1.0
        for h in range(3):
            if h == 2 and rnd >= 3:
                continue
            delivered.append(next(streams[h]))
            agents[h].observe(data_s=0.001, step_s=0.1)
        coord.poll()
    clock[0] += 10.0
    for h in (0, 1):
        agents[h].heartbeat()
    assert any(a["kind"] == "reshard" for a in coord.poll())
    # host1 dies immediately after — likely still holding makeup
    clock[0] += 1.0
    delivered.append(next(streams[0]))
    agents[0].observe(data_s=0.001, step_s=0.1)
    clock[0] += 10.0
    agents[0].heartbeat()
    assert any(a["kind"] == "reshard" for a in coord.poll())
    while streams[0].position < n // gb:
        delivered.append(next(streams[0]))
    assert _flat_indices(delivered) == list(range(n))


# --------------------------------------------------------------------------
# satellites: adaptive budget + Welch win test
# --------------------------------------------------------------------------
def test_adaptive_budget_derives_from_search_space():
    cfg = DPTConfig(num_cpu_cores=12, num_devices=4)
    assert adaptive_budget(cfg) == 36          # 3x the deepest rung (12)
    assert adaptive_budget(cfg, explicit=5) == 5
    assert adaptive_budget(DPTConfig(num_cpu_cores=2, num_devices=1)) == 8


def test_online_tuner_uses_adaptive_budget_when_unset():
    ev = _table_evaluator(lambda i, j: 4.0 / i + 0.1 * j)
    dl = DataLoader(_index_dataset(64), 8, shuffle=False, seed=0,
                    params=LoaderParams(num_workers=1, prefetch_factor=1))
    tuner = OnlineTuner(dl, evaluator=ev,
                        config=OnlineTunerConfig(num_cpu_cores=4,
                                                 num_devices=1,
                                                 max_prefetch=2),
                        machine_fp="m", dataset_fp="d")
    tuner.force_retune()
    assert ev.budgets and all(b == 12 for b in ev.budgets)   # 3 * 4 cores


def test_welch_wins_separates_signal_from_noise():
    slow = [1.00, 1.02, 0.98, 1.01, 0.99, 1.00]
    fast = [0.50, 0.52, 0.49, 0.51, 0.50, 0.48]
    assert welch_wins(slow, fast)
    assert not welch_wins(fast, slow)          # one-sided
    noisy_a = [1.0, 0.2, 1.8, 0.6, 1.4]
    noisy_b = [0.9, 0.3, 1.7, 0.5, 1.5]        # same spread, tiny shift
    assert not welch_wins(noisy_a, noisy_b)
    assert not welch_wins([1.0], [0.5])        # too few samples


def test_retune_policy_welch_blocks_noisy_win():
    """A 'winner' whose mean is lower only within noise is not applied;
    a clearly separated one is."""
    cfg = OnlineTunerConfig(strategy="hillclimb", min_improvement=0.05)
    policy = RetunePolicy(cfg)
    current = LoaderParams(num_workers=1, prefetch_factor=1)

    def result(win_samples):
        ref = Trial(1, 1, 1.0, batch_seconds=[1.0, 0.6, 1.4, 0.8, 1.2])
        win = Trial(4, 1, 0.9, batch_seconds=win_samples)
        return DPTResult(4, 1, 0.9, [ref, win])

    noisy = result([0.9, 0.5, 1.5, 0.7, 1.3])       # -10% mean, huge var
    assert not policy.is_win(noisy, current)
    clear = result([0.30, 0.32, 0.28, 0.31, 0.29])  # unambiguous
    assert policy.is_win(clear, current)


def test_retune_policy_falls_back_without_samples():
    cfg = OnlineTunerConfig(strategy="hillclimb", min_improvement=0.05)
    policy = RetunePolicy(cfg)
    current = LoaderParams(num_workers=1, prefetch_factor=1)
    res = DPTResult(4, 1, 0.5, [Trial(1, 1, 1.0), Trial(4, 1, 0.5)])
    assert policy.is_win(res, current)
    res_small = DPTResult(4, 1, 0.97, [Trial(1, 1, 1.0), Trial(4, 1, 0.97)])
    assert not policy.is_win(res_small, current)


def test_loader_evaluator_records_batch_seconds():
    """Wall-clock trials carry per-batch samples for the Welch test."""
    from repro.tuning import TrialRecorder
    from repro.core.evaluators import LoaderEvaluator
    dl = DataLoader(_index_dataset(64), 8, shuffle=False, seed=0,
                    params=LoaderParams(num_workers=0))
    rec = TrialRecorder(LoaderEvaluator(dl, to_device=False),
                        DPTConfig(num_batches=4))
    rec.seconds(0, 1)
    assert len(rec.trials) == 1
    assert len(rec.trials[0].batch_seconds) == 4
