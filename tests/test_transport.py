"""The survivable control plane (ISSUE 7, DESIGN.md §8): transport
primitives, the host link's partition tolerance, coordinator fencing,
snapshot/restore, and the standby failover state machine.

Everything here runs over the in-process LocalTransport/FaultyTransport —
the same message shapes a gRPC backend would carry — with fake clocks, so
every partition, crash, and promotion is deterministic.
"""
import dataclasses

import numpy as np
import pytest

from conftest import flat_indices, make_index_dataset, make_table_evaluator

from repro.data import DataLoader, LoaderParams
from repro.tuning import (FaultSpec, FaultyTransport, FleetConfig,
                          FleetCoordinator, LeaderLease, LinkConfig,
                          LocalTransport, SnapshotStore, StaleLeaderError,
                          TransportError, connect_host)
from repro.tuning.fleet import CoordinatorServer, EventLog, HostReport
from repro.tuning.transport import (AgentLink, encode_report_delta,
                                    merge_report_delta, payload_bytes,
                                    to_wire)


# --------------------------------------------------------------------------
# wire encoding
# --------------------------------------------------------------------------
def test_to_wire_normalizes_everything():
    @dataclasses.dataclass
    class Rec:
        xs: tuple
        arr: np.ndarray

    wire = to_wire({"rec": Rec((1, 2), np.arange(3, dtype=np.int64)),
                    "scalar": np.float64(1.5),
                    3: "int-key"})
    assert wire == {"rec": {"xs": [1, 2], "arr": [0, 1, 2]},
                    "scalar": 1.5, "3": "int-key"}
    # JSON-able end to end — what a real wire requires
    assert payload_bytes(wire) > 0


def _report_dict(steps, *, consumed=None, io=None):
    return to_wire({
        "host": "h0", "steps": steps,
        "consumed": consumed if consumed is not None else steps,
        "position": steps + 2, "stall_ratio": 0.1, "steps_per_s": 20.0,
        # rolling window: one append per step, newest 8 retained
        "batch_seconds": [0.05 * (i + 1) for i in range(steps)][-8:],
        "params": [2, 2], "io": io, "makeup_done": 0})


def test_report_delta_roundtrip_and_smaller():
    base = _report_dict(8, io={"storage_requests": 64.0, "run_len": 8.0})
    cur = _report_dict(9, io={"storage_requests": 72.0, "run_len": 8.0})
    delta = encode_report_delta(base, cur)
    assert merge_report_delta(base, delta) == cur
    # only the changed io key crosses; the rolling window sends its tail
    assert delta["io"] == {"storage_requests": 72.0}
    assert len(delta["bs_tail"]) == 1
    wire = {"kind": "report", "host": "h0", "delta": True,
            "base": 8, "patch": delta}
    full = {"kind": "report", "host": "h0", "reports": [cur]}
    assert payload_bytes(wire) < payload_bytes(full)


def test_report_delta_identical_report_is_empty():
    base = _report_dict(8)
    assert encode_report_delta(base, dict(base)) == {}


# --------------------------------------------------------------------------
# fault injection
# --------------------------------------------------------------------------
def _echo_transport(faults=None):
    t = FaultyTransport(faults or FaultSpec())
    calls = []
    t.register("dst", lambda m: calls.append(m) or {"ok": True})
    return t, calls


def test_faulty_transport_deterministic_by_seed():
    def outcomes(seed):
        t, _ = _echo_transport(FaultSpec(drop=0.3, delay=0.2, duplicate=0.2,
                                         reply_drop=0.2, seed=seed))
        out = []
        for i in range(40):
            try:
                t.call("src", "dst", {"kind": "m", "i": i})
                out.append("ok")
            except TransportError as e:
                out.append(str(e).split(": ")[-1])
        return out

    assert outcomes(7) == outcomes(7)
    assert outcomes(7) != outcomes(8)


def test_partition_cuts_both_ways_and_heals():
    t, calls = _echo_transport()
    t.register("src", lambda m: {"ok": True})
    t.partition("src", "dst")
    for a, b in (("src", "dst"), ("dst", "src")):
        with pytest.raises(TransportError, match="partition"):
            t.call(a, b, {"kind": "m"})
    t.heal("src")
    assert t.call("src", "dst", {"kind": "m"})["ok"]
    assert len(calls) == 1


def test_delayed_message_arrives_stale_at_pump():
    t, calls = _echo_transport(FaultSpec(delay=1.0))
    with pytest.raises(TransportError, match="delayed"):
        t.call("src", "dst", {"kind": "m", "i": 0})
    assert calls == []                     # parked, not delivered
    assert t.pump() == 1                   # ... until pumped
    assert calls == [{"kind": "m", "i": 0}]
    assert t.pump() == 0                   # delivered once, not forever


# --------------------------------------------------------------------------
# lease + snapshot store
# --------------------------------------------------------------------------
def test_lease_fence_monotonic_across_acquisitions():
    clock = [0.0]
    lease = LeaderLease(ttl_s=5.0, clock=lambda: clock[0])
    assert lease.acquire("a") == 1
    assert lease.acquire("b") is None      # held
    assert lease.acquire("a") == 1         # holder re-acquire = refresh
    clock[0] += 6.0                        # expire
    assert lease.holder() is None
    assert lease.acquire("b") == 2         # fence strictly increases
    assert not lease.refresh("a")          # deposed holder cannot refresh
    assert lease.refresh("b")


def test_snapshot_store_never_aliases_live_state():
    store = SnapshotStore()
    state = {"xs": [1, 2]}
    seq = store.put(state)
    state["xs"].append(3)                  # live mutation after the put
    assert store.get() == {"xs": [1, 2]}
    got = store.get()
    got["xs"].append(9)                    # reader mutation
    assert store.get() == {"xs": [1, 2]}
    assert store.put(state) == seq + 1


# --------------------------------------------------------------------------
# the host link
# --------------------------------------------------------------------------
class _Sink:
    """Minimal coordinator endpoint: acks reports, records them."""

    def __init__(self, transport, *, fence=0):
        self.fence = fence
        self.reports = []
        self.need_full_once = False
        transport.register("coord", self.handle, replace=True)

    def handle(self, msg):
        if msg.get("kind") == "report":
            if msg.get("delta") and self.need_full_once:
                self.need_full_once = False
                return {"ok": False, "need_full": True, "fence": self.fence}
            self.reports.append(msg)
            return {"ok": True, "fence": self.fence}
        return {"ok": True, "fence": self.fence}


class _CmdAgent:
    """Records handle_command invocations (the link dispatches to this)."""

    def __init__(self):
        self.calls = []

    def handle_command(self, op, args):
        self.calls.append((op, dict(args)))
        return {"seen": len(self.calls)}


def test_link_bounded_queue_backoff_never_blocks():
    clock = [0.0]
    t = LocalTransport()
    _Sink(t)
    link = AgentLink(t, "h0", config=LinkConfig(max_queue=4, retries=2,
                                                backoff_s=1.0, jitter=0.0),
                     clock=lambda: clock[0])
    link.agent = _CmdAgent()
    t.unregister("coord")                  # the coordinator goes away
    sent_calls_before = t.sent_msgs
    for i in range(10):
        assert not link.send_report(_report_dict(i))
        clock[0] += 0.01                   # backoff window: most sends park
    # bounded: the queue holds the newest 4, the overflow was counted
    assert len(link._pending) == 4
    assert link.dropped_reports == 6
    assert not link.connected
    # backoff: only the first send actually attempted delivery; the rest
    # parked without a flush.  Nothing was accounted as wire traffic —
    # connection-refused fails fast, pre-serialization, so a dead
    # coordinator costs the training loop ~nothing
    assert link.send_failures == 1
    assert t.sent_msgs == sent_calls_before
    # exponential growth capped
    assert link._backoff == min(link.cfg.max_backoff_s,
                                1.0 * link.cfg.backoff_mult)


def test_link_replays_backlog_in_order_on_reconnect():
    clock = [0.0]
    t = FaultyTransport()
    sink = _Sink(t)
    link = AgentLink(t, "h0", config=LinkConfig(max_queue=8, retries=1,
                                                backoff_s=0.1, jitter=0.0),
                     clock=lambda: clock[0])
    link.agent = _CmdAgent()
    assert link.send_report(_report_dict(1))
    t.partition("h0", "coord")
    for i in range(2, 5):
        clock[0] += 1.0
        link.send_report(_report_dict(i))
    t.heal("h0", "coord")
    clock[0] += 1.0
    assert link.send_report(_report_dict(5))
    # the reconnect message carried the whole parked backlog, in order
    replay = sink.reports[-1]["reports"]
    assert [r["steps"] for r in replay] == [2, 3, 4, 5]
    assert link.connected


def test_link_delta_protocol_self_heals_on_need_full():
    clock = [0.0]
    t = LocalTransport()
    sink = _Sink(t)
    link = AgentLink(t, "h0", config=LinkConfig(jitter=0.0),
                     clock=lambda: clock[0])
    link.agent = _CmdAgent()
    link.send_report(_report_dict(1))      # first is always full
    link.send_report(_report_dict(2))      # then deltas
    assert link.full_sent == 1 and link.delta_sent == 1
    sink.need_full_once = True             # a failed-over server lost the base
    link.send_report(_report_dict(3))
    assert link.full_sent == 2             # one full resend, no operator help
    link.send_report(_report_dict(4))
    assert link.delta_sent == 2            # ... and deltas resume


def test_link_rejects_stale_fence_and_dedups_commands():
    t = LocalTransport()
    _Sink(t, fence=3)
    agent = _CmdAgent()
    link = AgentLink(t, "h0").bind(agent)
    link.fence = 3
    cmd = {"kind": "cmd", "op": "apply_params", "args": {"nworker": 4},
           "fence": 3, "id": "op-1"}
    r1 = t.call("coord", "h0", cmd)
    r2 = t.call("coord", "h0", dict(cmd))          # duplicate delivery
    assert r1["ok"] and r2 == r1
    assert len(agent.calls) == 1                   # executed exactly once
    stale = t.call("coord", "h0", {"kind": "cmd", "op": "apply_params",
                                   "args": {}, "fence": 2, "id": "op-0"})
    assert not stale["ok"] and stale["error"] == "stale-fence"
    assert len(agent.calls) == 1                   # never reached the agent
    assert link.rejected[-1]["fence"] == 2
    # a NEWER fence is adopted: the link follows the new leader
    t.call("coord", "h0", {"kind": "cmd", "op": "ping", "args": {},
                           "fence": 5, "id": "op-2"})
    assert link.fence == 5


# --------------------------------------------------------------------------
# coordinator satellites: per-instance config, ingest guard, event ring,
# barrier cap
# --------------------------------------------------------------------------
def test_fleet_config_not_shared_between_coordinators():
    a, b = FleetCoordinator(), FleetCoordinator()
    assert a.cfg is not b.cfg
    a.cfg.heartbeat_timeout_s = 1.0
    assert b.cfg.heartbeat_timeout_s == 30.0


def _mk_report(steps, *, consumed=None, batch_s=0.05):
    return HostReport(host="h0", steps=steps,
                      consumed=consumed if consumed is not None else steps,
                      position=steps + 2, stall_ratio=0.0, steps_per_s=20.0,
                      batch_seconds=[batch_s], params=(2, 2))


def test_ingest_rejects_stale_and_duplicate_reports():
    clock = [0.0]
    coord = FleetCoordinator(config=FleetConfig(heartbeat_timeout_s=5.0),
                             clock=lambda: clock[0])
    assert coord.ingest(_mk_report(5))
    straggler_windows = len(coord.straggler.state_dict().get("h0", []))
    # a duplicate and a reordered replay: rejected, bookkeeping frozen
    assert not coord.ingest(_mk_report(5, batch_s=9.0))
    assert not coord.ingest(_mk_report(3, consumed=1, batch_s=9.0))
    assert coord.stale_reports == 2
    assert coord.reports["h0"].consumed == 5       # never rewound
    assert len(coord.straggler.state_dict()["h0"]) == straggler_windows
    # ... but stale bytes still arrived NOW: they count as liveness
    clock[0] += 4.0
    assert not coord.ingest(_mk_report(5))
    assert "h0" in coord.registry.alive_hosts()
    # fresh progress is accepted again
    assert coord.ingest(_mk_report(6))
    assert coord.reports["h0"].steps == 6


def test_ingest_guard_resets_for_a_reregistered_host(fleet_factory):
    fleet = fleet_factory(hosts=2)
    agent = fleet.agents[0]
    for _ in range(3):
        next(fleet.streams[0])
        agent.observe(data_s=0.001, step_s=0.05)
    assert fleet.coord._last_steps[agent.host] == 3
    # the host restarts: steps counter rewinds to 1 — re-registration must
    # not leave its new life permanently muted
    agent.steps = 0
    fleet.coord.register(agent)
    next(fleet.streams[0])
    agent.observe(data_s=0.001, step_s=0.05)
    assert fleet.coord.reports[agent.host].steps == 1


def test_event_log_ring_bounded_with_stable_seq():
    log = EventLog(max_events=4)
    for i in range(10):
        log.append({"kind": "e", "i": i})
    assert len(log) == 4
    assert [e["i"] for e in log] == [6, 7, 8, 9]
    assert [e["seq"] for e in log] == [6, 7, 8, 9]  # fleet-lifetime numbering
    assert log.next_seq == 10
    # list-ish surface the benches/tests rely on
    assert log[-1]["i"] == 9 and log[1:3][0]["i"] == 7 and bool(log)
    # the HA snapshot carries the ring AND the monotonic counter
    back = EventLog.restore(log.state_dict())
    assert [e["i"] for e in back] == [6, 7, 8, 9]
    back.append({"kind": "e", "i": 10})
    assert back[-1]["seq"] == 10


def test_coordinator_event_log_is_bounded():
    coord = FleetCoordinator(config=FleetConfig(max_events=8))
    for i in range(100):
        coord.events.append({"kind": "noise", "i": i})
    assert len(coord.events) == 8
    assert coord.events[-1]["seq"] == 99


class _BarrierRacer:
    """A misbehaving agent that raises its effective barrier forever."""

    def __init__(self, host):
        self.host = host
        self.calls = 0

    def stream_position(self):
        return 0

    def reshard(self, num_shards, shard, *, at_batch=None, makeup=None,
                sizes=None, op_id=None):
        self.calls += 1
        return (at_batch or 0) + 1


def test_barrier_negotiation_caps_reissue_loop():
    coord = FleetCoordinator(config=FleetConfig(max_barrier_rounds=5))
    racer = _BarrierRacer("evil")
    with pytest.raises(RuntimeError, match="5 rounds"):
        coord._negotiate_barrier([racer], 1, 0)
    assert racer.calls == 5


# The transport-mode fleet harness (``WireFleet`` / ``wire_fleet``) lives
# in conftest.py — the property matrix in test_properties.py drives the
# same machinery.

# --------------------------------------------------------------------------
# HA: snapshot/restore, partition tolerance, failover, fencing
# --------------------------------------------------------------------------
def test_coordinator_state_dict_restore_roundtrip(wire_fleet):
    fleet = wire_fleet()
    fleet.rounds(5)
    state = fleet.coord.state_dict()
    back = FleetCoordinator.restore(state, clock=lambda: fleet.clock[0])
    assert back.cfg == fleet.coord.cfg
    assert sorted(back.reports) == sorted(fleet.coord.reports)
    assert back._last_steps == fleet.coord._last_steps
    assert back.events.next_seq == fleet.coord.events.next_seq
    assert back.reshards == fleet.coord.reshards
    # members materialize as proxies when a server binds
    server2 = CoordinatorServer(back, LocalTransport(), name="coord2",
                                owner="coord-1")
    assert sorted(back.agents) == sorted(fleet.coord.agents)
    for h, proxy in back.agents.items():
        live = fleet.coord.agents[h]
        assert proxy.param_cell() == live.param_cell()
        assert proxy.shard_index() == live.shard_index()
        assert proxy.batches_per_epoch() == live.batches_per_epoch()
    assert server2.fence == 0
    # restore normalized through the wire: a snapshot is JSON, not objects
    assert payload_bytes(state) > 0


def test_partitioned_host_keeps_streaming_and_resyncs(wire_fleet):
    fleet = wire_fleet()
    fleet.rounds(3)
    link = fleet.agents[2].link
    fleet.transport.partition("host2", "coord")
    # the host never blocks: it keeps pulling batches on latched params
    # while every report parks in the bounded queue
    fleet.rounds(3)
    assert not link.connected
    assert len(link._pending) > 0
    pos_during = fleet.streams[2].position
    assert pos_during >= 6                  # streamed right through the cut
    # while it was gone, the fleet pushed new uniform params
    for i in (0, 1):
        fleet.agents[i].apply_params(4, 1)
    fleet.coord._pushed = {"cell": [4, 1], "schedule": None}
    fleet.transport.heal("host2", "coord")
    fleet.rounds(2)
    # reconnect: backlog replayed, report accepted, catch-up re-pushed the
    # cell the host missed
    assert link.connected
    assert fleet.agents[2].param_cell() == (4, 1)
    assert "host2" in fleet.coord.reports


def test_failover_promotes_standby_with_fresh_fence(wire_fleet):
    fleet = wire_fleet()
    fleet.rounds(4)
    old_server = fleet.server
    old_fence = old_server.fence
    old_server.crash()
    # outage: hosts keep streaming; lease expires; standby promotes
    fleet.rounds(6)
    assert fleet.replica.promoted
    assert fleet.server is not old_server
    assert fleet.server.fence > old_fence
    assert sorted(fleet.coord.agents) == ["host0", "host1", "host2"]
    # every host followed the new leader...
    fleet.rounds(2)
    assert all(a.link.fence == fleet.server.fence for a in fleet.agents)
    # ... and the deposed leader's commands are rejected everywhere
    with pytest.raises(StaleLeaderError):
        old_server.send("host0", "ping", {})
    assert old_server.deposed
    assert fleet.agents[0].link.rejected[-1]["fence"] == old_fence
    # the promotion is on the record with the fleet-lifetime seq intact
    kinds = [e["kind"] for e in fleet.coord.events]
    assert "promote" in kinds
    # no host was declared dead by the outage itself (registry re-armed)
    assert not fleet.coord.registry.dead_hosts()
    fleet.drain(range(3))
    assert flat_indices(fleet.delivered) == list(range(fleet.n))


def test_failover_completes_epoch_after_host_death(wire_fleet):
    """Primary crashes BEFORE it can react to a dead host: the promoted
    standby detects the death from restored state, reshards the survivors
    over the wire, and the epoch still covers every index exactly once."""
    fleet = wire_fleet(heartbeat_timeout=4.0)
    fleet.rounds(3)
    fleet.server.crash()
    # host2 dies during the outage
    fleet.rounds(2, alive=[0, 1])
    fleet.rounds(8, alive=[0, 1])          # promote + detect + reshard
    assert fleet.replica.promoted
    reshards = [e for e in fleet.coord.events if e["kind"] == "reshard"]
    assert len(reshards) == 1 and reshards[0]["lost"] == ["host2"]
    fleet.drain([0, 1])
    assert flat_indices(fleet.delivered) == list(range(fleet.n))


def test_leader_crash_mid_makeup_deal_is_exactly_once(wire_fleet):
    """The WAL + op-id dedup contract: the leader dies after dealing SOME
    makeup shares; the promoted standby re-deals only the rest, and a
    share that was already applied is never applied twice."""
    fleet = wire_fleet(heartbeat_timeout=4.0)
    fleet.rounds(3)

    # make host1 execute-but-drop-reply on add_makeup: the deal applies,
    # the leader sees a timeout (the two-generals corner the op-ids exist
    # for), and _reshard_around raises out of the deal loop
    real = fleet.transport._endpoints["host1"]
    state = {"fail": True}

    def flaky(msg):
        reply = real(msg)
        if state["fail"] and msg.get("kind") == "cmd" \
                and msg.get("op") == "add_makeup":
            raise TransportError("host1: reply dropped")
        return reply

    fleet.transport.register("host1", flaky, replace=True)

    # host2 dies; the leader's next polls detect it and start the reshard
    for _ in range(10):
        fleet.rounds(1, alive=[0, 1])
        if fleet.coord._pending_reshard is not None \
                or any(e["kind"] == "reshard" for e in fleet.coord.events):
            break
    # the deal was interrupted: the write-ahead intent survived
    pending = fleet.coord._pending_reshard
    assert pending is not None and pending["stage"] == "deal"
    applied_before = {h: fleet.agents[i]._makeup_added
                      for i, h in ((0, "host0"), (1, "host1"))}
    assert any(v > 0 for v in applied_before.values())

    fleet.server.crash()
    state["fail"] = False                   # the wire heals with the old
    fleet.transport.register("host1", real, replace=True)  # leader dead
    fleet.rounds(8, alive=[0, 1])           # standby promotes + replays
    assert fleet.replica.promoted
    assert fleet.coord._pending_reshard is None
    replayed = [e for e in fleet.coord.events if e["kind"] == "reshard"]
    assert len(replayed) == 1 and replayed[0]["reason"].endswith("+replay")

    # exactly-once: host1's flaky share was NOT re-applied (op-id dedup
    # returned the cached ack), host0 kept its single application
    shares = {h: len(s) for h, s in (pending.get("shares") or {}).items()}
    for i, h in ((0, "host0"), (1, "host1")):
        assert fleet.agents[i]._makeup_added == shares.get(h, 0)
    fleet.drain([0, 1])
    assert flat_indices(fleet.delivered) == list(range(fleet.n))


def test_live_leader_resumes_interrupted_deal(wire_fleet):
    """Same interrupted reshard, but the leader SURVIVES: its own next
    poll resumes the write-ahead intent once the wire heals — failover is
    not required for the fleet to finish a reshard.  The cut is inbound-
    only (host1 still reports, its commands bounce) so the host stays
    alive while the reshard around dead host2 cannot reach it."""
    fleet = wire_fleet(heartbeat_timeout=4.0)
    fleet.rounds(3)
    real = fleet.transport._endpoints["host1"]
    state = {"cut": True}

    def inbound_cut(msg):
        if state["cut"] and msg.get("kind") == "cmd":
            raise TransportError("host1: unreachable for commands")
        return real(msg)

    fleet.transport.register("host1", inbound_cut, replace=True)
    for _ in range(10):
        fleet.rounds(1, alive=[0, 1])      # host2 goes silent and dies
        if fleet.coord._pending_reshard is not None:
            break
    assert fleet.coord._pending_reshard is not None
    assert not any(e["kind"] == "reshard" for e in fleet.coord.events)
    state["cut"] = False
    fleet.rounds(2, alive=[0, 1])
    assert fleet.coord._pending_reshard is None
    assert any(e["kind"] == "reshard" for e in fleet.coord.events)
    fleet.drain([0, 1])
    assert flat_indices(fleet.delivered) == list(range(fleet.n))


def test_wire_fleet_consensus_and_heartbeat_traffic_is_delta(wire_fleet):
    """Steady-state heartbeat traffic is O(hosts): after the first beat
    every report crosses as a delta, measurably smaller than the fulls,
    and a consensus runs end-to-end over the wire (remote evaluators)."""
    fleet = wire_fleet()
    fleet.coord.request_consensus(reason="startup")
    fleet.rounds(8)
    assert fleet.coord.consensus_runs >= 1
    cell = {a.param_cell() for a in fleet.agents}
    assert len(cell) == 1                   # uniform push landed everywhere
    srv = fleet.server
    assert srv.report_delta_msgs > srv.report_full_msgs
    assert (srv.report_delta_bytes / max(1, srv.report_delta_msgs)) < \
        (srv.report_full_bytes / max(1, srv.report_full_msgs))
    fleet.drain(range(3))
    assert flat_indices(fleet.delivered) == list(range(fleet.n))


def test_evicted_host_stops_and_rejoins_cleanly(wire_fleet):
    """A partition OUTLASTING the heartbeat timeout gets the host
    resharded around; on heal the host learns it was evicted (stops
    reporting) and can rejoin as a fresh member."""
    fleet = wire_fleet(heartbeat_timeout=3.0)
    fleet.rounds(3)
    fleet.transport.partition("host2", "coord")
    for _ in range(12):
        fleet.rounds(1, alive=[0, 1])      # host2's old batches are void:
        if any(e["kind"] == "reshard" for e in fleet.coord.events):
            break
    assert any(e["kind"] == "reshard" for e in fleet.coord.events)
    fleet.transport.heal("host2", "coord")
    link2 = fleet.agents[2].link
    link2.send_report(fleet.agents[2].report_wire())
    assert link2.evicted and not link2.connected
    assert "host2" not in fleet.coord.agents
    # rejoin with a FRESH stream (the old shard map is void)
    fleet.streams[2].close()
    dl = DataLoader(make_index_dataset(fleet.n), fleet.gb, shuffle=True,
                    seed=5, params=LoaderParams(num_workers=2,
                                                prefetch_factor=2),
                    host_index=2, host_count=3)
    fleet.agents[2] = connect_host(
        fleet.transport, "host2", dl,
        evaluator=make_table_evaluator(lambda i, j: 4.0 / i + 0.1 * j),
        clock=lambda: fleet.clock[0], join=True,
        link_config=LinkConfig(seed=2, jitter=0.0))
    fleet.streams[2] = dl.stream(to_device=False)
    assert "host2" in fleet.coord.agents
    fleet.rounds(2)
    assert fleet.agents[2].link.connected
