"""Unified tuning layer: registry/front door parity, hot-swap of a live
DataLoader, and the OnlineTuner drift loop."""
import math

import numpy as np
import pytest

from repro.core import (DPT, DPTConfig, LoaderSimulator, MachineProfile,
                        MemoryOverflow, SimulatorEvaluator)
from repro.core.cache import DPTCache
from repro.core.cluster import degraded_storage
from repro.core.search import (goodput_tune, successive_halving,
                               tuned_with_warmstart)
from repro.data import DataLoader, Dataset, LoaderParams
from repro.data.loader import TransferStats
from repro.data.storage import ArrayStorage, cifar10_profile, coco_profile
from repro.tuning import (OnlineTuner, OnlineTunerConfig, available_strategies,
                          register_strategy, tune, worker_rungs)


# --------------------------------------------------------------------------
# registry + front door
# --------------------------------------------------------------------------
def test_registry_has_all_builtin_strategies():
    names = available_strategies()
    for expected in ("grid", "successive_halving", "hillclimb",
                     "warmstart_hillclimb", "goodput"):
        assert expected in names


def test_unknown_strategy_raises():
    with pytest.raises(ValueError, match="unknown tuning strategy"):
        tune(evaluator=lambda *a, **k: None, strategy="nope")


def test_custom_strategy_registration():
    @register_strategy("always_one_worker")
    class AlwaysOne:
        def tune(self, rec, **kw):
            t = rec.seconds(1, 1)
            return rec.result(1, 1, t)

    ev = _table(lambda i, j: float(i + j))
    res = tune(evaluator=ev, strategy="always_one_worker",
               config=DPTConfig(num_cpu_cores=4, num_devices=1))
    assert (res.nworker, res.nprefetch) == (1, 1)
    assert len(res.trials) == 1


def test_worker_rungs_clamped():
    assert worker_rungs(12, 4) == [4, 8, 12]
    assert worker_rungs(10, 4) == [4, 8, 10]
    assert worker_rungs(2, 4) == [2]


def _table(fn, overflow=None):
    overflow = overflow or (lambda i, j: False)

    def ev(i, j, *, num_batches=16, epoch=0):
        ev.calls += 1
        if overflow(i, j):
            raise MemoryOverflow(f"cell ({i},{j})")
        return TransferStats(fn(i, j), num_batches, 0)

    ev.calls = 0
    return ev


# --------------------------------------------------------------------------
# parity: the front door returns what the legacy entry points return on the
# simulator profiles used across tests/test_dpt.py (acceptance criterion)
# --------------------------------------------------------------------------
CFG = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=8,
                num_batches=64)


def _sim_ev():
    return SimulatorEvaluator(LoaderSimulator(cifar10_profile(),
                                              MachineProfile()),
                              batch_size=32)


def test_front_door_grid_matches_dpt_run():
    a = tune(evaluator=_sim_ev(), strategy="grid", config=CFG,
             measure_default=False)
    b = DPT(_sim_ev(), CFG).run(measure_default=False)
    assert (a.nworker, a.nprefetch, a.optimal_time) == \
        (b.nworker, b.nprefetch, b.optimal_time)
    assert len(a.trials) == len(b.trials)


def test_front_door_successive_halving_matches_legacy():
    a = tune(evaluator=_sim_ev(), strategy="successive_halving", config=CFG)
    b = successive_halving(_sim_ev(), config=CFG)
    assert (a.nworker, a.nprefetch, a.optimal_time) == \
        (b.nworker, b.nprefetch, b.optimal_time)


def test_front_door_warmstart_matches_legacy():
    a = tune(evaluator=_sim_ev(), strategy="warmstart_hillclimb", config=CFG,
             storage=cifar10_profile(), machine=MachineProfile(),
             batch_size=32)
    b = tuned_with_warmstart(_sim_ev(), cifar10_profile(), MachineProfile(),
                             batch_size=32, config=CFG)
    assert (a.nworker, a.nprefetch, a.optimal_time) == \
        (b.nworker, b.nprefetch, b.optimal_time)


def test_front_door_goodput_matches_legacy():
    a = tune(evaluator=_sim_ev(), strategy="goodput", config=CFG,
             step_time_s=1.0, num_batches=64)
    b = goodput_tune(_sim_ev(), step_time_s=1.0, num_batches=64, config=CFG)
    assert (a.nworker, a.nprefetch, a.optimal_time) == \
        (b.nworker, b.nprefetch, b.optimal_time)


def test_overflow_recorded_as_inf_trial():
    ev = _table(lambda i, j: 5.0 - i, overflow=lambda i, j: j >= 2)
    res = tune(evaluator=ev, strategy="grid", measure_default=False,
               config=DPTConfig(num_cpu_cores=2, num_devices=1,
                                max_prefetch=4, num_batches=2))
    assert any(t.overflowed and not math.isfinite(t.seconds)
               for t in res.trials)
    assert res.nprefetch == 1        # overflow broke the inner sweep


# --------------------------------------------------------------------------
# hot swap of a live stream
# --------------------------------------------------------------------------
def _index_dataset(n):
    """Items carry their own index so batches are accountable."""
    items = [np.full((4,), i, np.int32) for i in range(n)]
    return Dataset(ArrayStorage(items), transform=lambda a: {"x": a})


def _indices(batches):
    return sorted(np.concatenate([b["x"][:, 0] for b in batches]).tolist())


def test_hot_swap_zero_lost_zero_duplicated_batches():
    """Index accounting across two mid-epoch swaps (acceptance criterion).

    A drain boundary is a total flush: everything the outgoing pool pulled
    from the sampler has been delivered, and the incoming pool continues
    from exactly that position.  So at each completed swap the batches
    delivered so far must be EXACTLY the first k global batches — any lost
    batch leaves a hole, any duplicate shows up twice.  (Mid-stream, racing
    workers may deliver out of order, so only drain boundaries admit an
    exact check.)"""
    n, gb = 1024, 8
    dl = DataLoader(_index_dataset(n), gb, shuffle=False, seed=0,
                    params=LoaderParams(num_workers=2, prefetch_factor=2))
    stream = dl.stream(to_device=False)

    consumed = [next(stream) for _ in range(10)]
    dl.apply_params(LoaderParams(num_workers=4, prefetch_factor=3))
    while stream.swaps == 0:
        consumed.append(next(stream))
    b1 = len(consumed) - 1           # first post-swap batch just arrived
    assert _indices(consumed[:b1]) == list(range(b1 * gb))
    assert dl.params.num_workers == 4 and dl.params.prefetch_factor == 3

    consumed += [next(stream) for _ in range(10)]
    dl.apply_params(LoaderParams(num_workers=2, prefetch_factor=2))
    while stream.swaps == 1:
        consumed.append(next(stream))
    b2 = len(consumed) - 1
    assert b1 < b2 < n // gb         # still mid-epoch
    assert _indices(consumed[:b2]) == list(range(b2 * gb))
    assert stream.swaps == 2
    assert dl.params.num_workers == 2


def test_hot_swap_preserves_sampler_position_mid_epoch():
    """Single-worker pools are order-deterministic: the swapped stream must
    produce the exact same batch sequence as an untouched loader."""
    n, gb = 128, 8
    mk = lambda: DataLoader(_index_dataset(n), gb, shuffle=True, seed=7,
                            params=LoaderParams(num_workers=1,
                                                prefetch_factor=2))
    ref = [b["x"] for _, b in zip(range(2 * n // gb),
                                  mk().stream(to_device=False))]

    dl = DataLoader(_index_dataset(n), gb, shuffle=True, seed=7,
                    params=LoaderParams(num_workers=1, prefetch_factor=2))
    stream = dl.stream(to_device=False)
    got = [next(stream)["x"] for _ in range(5)]
    dl.apply_params(LoaderParams(num_workers=1, prefetch_factor=4))
    got += [next(stream)["x"] for _ in range(2 * n // gb - 5)]
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)
    assert stream.swaps == 1


def test_hot_swap_from_zero_workers():
    n, gb = 64, 8
    dl = DataLoader(_index_dataset(n), gb, shuffle=False, seed=0,
                    params=LoaderParams(num_workers=0))
    stream = dl.stream(to_device=False)
    consumed = [next(stream) for _ in range(3)]
    # swap to a single worker: delivery stays ordered, so consuming
    # exactly one epoch's worth of batches must cover the epoch exactly
    dl.apply_params(LoaderParams(num_workers=1, prefetch_factor=2))
    while len(consumed) < n // gb:
        consumed.append(next(stream))
    assert _indices(consumed) == list(range(n))
    assert stream.swaps == 1


def test_apply_params_without_stream_sets_params():
    dl = DataLoader(_index_dataset(32), 8)
    dl.apply_params(LoaderParams(num_workers=3, prefetch_factor=5))
    assert dl.params.num_workers == 3


# --------------------------------------------------------------------------
# OnlineTuner
# --------------------------------------------------------------------------
def _online_loader():
    return DataLoader(_index_dataset(64), 8, shuffle=False, seed=0,
                      params=LoaderParams(num_workers=1, prefetch_factor=1))


def _online_cfg(**kw):
    base = dict(stall_fraction=0.3, window=4, warmup_steps=2,
                cooldown_steps=6, retune_budget_batches=2, max_prefetch=3,
                num_cpu_cores=4, num_devices=1)
    base.update(kw)
    return OnlineTunerConfig(**base)


def test_online_tuner_retunes_on_goodput_drift(tmp_path):
    ev = _table(lambda i, j: 4.0 / i + 0.1 * j)     # optimum: many workers
    cache = DPTCache(str(tmp_path / "dpt.json"))
    dl = _online_loader()
    tuner = OnlineTuner(dl, evaluator=ev, cache=cache, config=_online_cfg(),
                        machine_fp="m", dataset_fp="d")
    # healthy phase: data fully hidden behind compute -> no retune
    for _ in range(8):
        assert tuner.observe(data_s=0.001, step_s=0.1) is None
    assert tuner.retunes == 0
    # drift: the step now stalls on data
    applied = None
    for _ in range(8):
        applied = applied or tuner.observe(data_s=0.09, step_s=0.1)
    assert applied is not None
    assert tuner.retunes == 1
    assert dl.params.num_workers == 4               # hillclimbed to the edge
    assert cache.get("m", "d", dl.global_batch) == (4, 1)


def test_online_tuner_respects_cooldown():
    ev = _table(lambda i, j: 1.0)
    tuner = OnlineTuner(_online_loader(), evaluator=ev,
                        config=_online_cfg(cooldown_steps=100),
                        machine_fp="m", dataset_fp="d")
    retunes = sum(
        tuner.observe(data_s=0.09, step_s=0.1) is not None
        for _ in range(40))
    assert retunes <= 1


def test_online_tuner_restores_params_when_search_overflows():
    ev = _table(lambda i, j: 1.0, overflow=lambda i, j: True)
    dl = _online_loader()
    orig = dl.params
    tuner = OnlineTuner(dl, evaluator=ev, config=_online_cfg(),
                        machine_fp="m", dataset_fp="d")
    assert tuner.force_retune() is None
    assert dl.params == orig
    assert tuner.retunes == 0


def test_online_tuner_restores_params_on_unexpected_error():
    """A non-MemoryOverflow evaluator crash mid-search must not leave a
    trial cell's params installed on the loader."""
    def ev(i, j, **kw):
        raise OSError("storage went away")

    dl = _online_loader()
    orig = dl.params
    tuner = OnlineTuner(dl, evaluator=ev, config=_online_cfg(),
                        machine_fp="m", dataset_fp="d")
    with pytest.raises(OSError):
        tuner.force_retune()
    assert dl.params == orig


def test_online_tuner_anti_churn_holds_off_lattice():
    """Current params not on the search lattice (e.g. grid's clamped rung
    with an incompatible G): the hillclimb's start trial is still the
    improvement reference, so a same-cost 'winner' is not applied."""
    ev = _table(lambda i, j: 1.0)                   # flat objective
    dl = DataLoader(_index_dataset(64), 8, shuffle=False, seed=0,
                    params=LoaderParams(num_workers=3, prefetch_factor=2))
    tuner = OnlineTuner(dl, evaluator=ev,
                        config=_online_cfg(num_cpu_cores=8, num_devices=2),
                        machine_fp="m", dataset_fp="d")
    assert tuner.force_retune() is None             # no >=5% win anywhere
    assert dl.params.num_workers == 3               # kept, not churned


def test_online_retune_recovers_within_10pct_of_scratch():
    """Simulated mid-run storage slowdown: a bounded hillclimb from the
    stale optimum must land within 10% of a from-scratch grid retune on
    the degraded profile (acceptance criterion; bench_online_drift.py
    reports the same numbers)."""
    machine = MachineProfile()
    healthy = coco_profile(160)
    degraded = degraded_storage(healthy, bw_scale=0.25, latency_scale=6.0)
    cfg = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=8,
                    num_batches=32)

    ev_h = SimulatorEvaluator(LoaderSimulator(healthy, machine),
                              batch_size=32)
    stale = tune(evaluator=ev_h, strategy="grid", config=cfg,
                 measure_default=False)

    mk = lambda: SimulatorEvaluator(LoaderSimulator(degraded, machine),
                                    batch_size=32)
    online_ev = mk()
    online = tune(evaluator=online_ev, strategy="hillclimb", config=cfg,
                  start=(stale.nworker, stale.nprefetch), max_steps=12)
    scratch = tune(evaluator=mk(), strategy="grid", config=cfg,
                   measure_default=False)
    assert online.optimal_time <= scratch.optimal_time * 1.10
    assert online_ev.calls < len(scratch.trials) / 2   # and it was cheaper


def test_apply_params_reaches_abandoned_stream_loader():
    """apply_params updates loader.params immediately even if the last
    stream was abandoned mid-iteration (future pools see new values)."""
    dl = DataLoader(_index_dataset(64), 8, shuffle=False, seed=0,
                    params=LoaderParams(num_workers=2, prefetch_factor=2))
    stream = dl.stream(to_device=False)
    next(stream)                      # consume one batch, then abandon
    dl.apply_params(LoaderParams(num_workers=5, prefetch_factor=3))
    assert dl.params.num_workers == 5
    assert dl.params.prefetch_factor == 3


def test_trainer_rejects_startup_incapable_strategy():
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.data import token_dataset
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    ds = token_dataset(64, 16, cfg.vocab_size, seed=1)
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=0), seed=1)
    tr = Trainer(model, dl,
                 TrainerConfig(autotune=True, autotune_strategy="goodput"))
    with pytest.raises(ValueError, match="cannot run at startup"):
        tr.tune_loader()
