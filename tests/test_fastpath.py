"""Zero-copy fast path: batched reads, slab-arena collation, ordered
delivery, coalesced latency accounting, and the donated device transfer."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.simulator import LoaderSimulator, MachineProfile
from conftest import make_index_dataset

from repro.data import (ArenaBatch, ArrayStorage, DataLoader, Dataset,
                        FileStorage, LatencyStorage, LoaderParams, SlabArena,
                        ShardedSampler, cifar10_profile, coalesce_runs,
                        coco_profile, synthetic_image_dataset, token_dataset)
from repro.data.arena import maybe_release
from repro.data.dataset import image_transform
from repro.data.prefetcher import DevicePrefetcher
from repro.data.worker_pool import ProcessWorkerPool, ThreadWorkerPool

FAST = LoaderParams(fast_path=True, zero_copy=True)
LEGACY = LoaderParams(fast_path=False)


# --------------------------------------------------------------------------
# batched collation == per-sample collation, byte for byte
# --------------------------------------------------------------------------
@pytest.mark.parametrize("mk", [
    lambda: synthetic_image_dataset(64, 16, seed=3),
    lambda: token_dataset(64, 12, 100, seed=3),
])
def test_batched_collation_matches_per_sample(mk):
    ds = mk()
    assert ds.supports_fast_path
    idx = np.arange(64)[7:31]
    slow = ds.get_batch(idx, fast=False)
    fast = ds.get_batch(idx, fast=True)
    assert set(slow) == set(fast)
    for k in slow:
        a, b = np.asarray(slow[k]), np.asarray(fast[k])
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes(), k


def test_batched_collation_into_preallocated_out():
    ds = synthetic_image_dataset(32, 8, seed=0)
    idx = np.arange(8)
    ref = ds.get_batch(idx, fast=False)
    out = {"image": np.empty((8, 8, 8, 3), np.float32),
           "label": np.empty((8,), np.int32)}
    got = ds.get_batch(idx, out=out)
    assert got is out and got["image"] is out["image"]
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]), got[k])
    # mismatched batch dim: out is ignored, fresh arrays returned
    got2 = ds.get_batch(np.arange(4), out=out)
    assert got2 is not out and got2["image"].shape[0] == 4


def test_swapping_transform_disables_fast_path():
    ds = synthetic_image_dataset(16, 8, seed=0)
    assert ds.supports_fast_path

    def boom(x):
        raise ValueError("boom")

    ds.transform = boom
    assert not ds.supports_fast_path
    with pytest.raises(ValueError, match="boom"):
        ds.get_batch(np.arange(4))


# --------------------------------------------------------------------------
# storage read_batch
# --------------------------------------------------------------------------
def test_array_storage_dense_gather():
    items = [np.full((3, 2), i, np.int16) for i in range(20)]
    st = ArrayStorage(items)
    got = st.read_batch([4, 9, 1])
    assert isinstance(got, np.ndarray) and got.shape == (3, 3, 2)
    np.testing.assert_array_equal(got[1], np.full((3, 2), 9, np.int16))
    # ragged items fall back to a list
    ragged = ArrayStorage([np.zeros(2), np.zeros(3)])
    out = ragged.read_batch([1, 0])
    assert isinstance(out, list) and out[0].shape == (3,)


def test_file_storage_caches_sizes_and_read_batch(tmp_path, monkeypatch):
    items = [np.arange(6, dtype=np.int64).reshape(2, 3) + i for i in range(5)]
    st = FileStorage.create(str(tmp_path), items)
    expected = [os.path.getsize(os.path.join(str(tmp_path), f"{i:08d}.npy"))
                for i in range(5)]
    calls = {"n": 0}
    real_getsize = os.path.getsize

    def counting_getsize(p):
        calls["n"] += 1
        return real_getsize(p)

    monkeypatch.setattr(os.path, "getsize", counting_getsize)
    for _ in range(3):                 # DPT's pre-check hammers these
        for i in range(5):
            assert st.item_nbytes(i) == expected[i]
    assert calls["n"] == 0             # sizes were stat'ed once, at init
    got = st.read_batch([2, 0, 4])
    for g, i in zip(got, [2, 0, 4]):
        np.testing.assert_array_equal(g, items[i])


def test_coalesce_runs():
    assert coalesce_runs([]) == []
    assert coalesce_runs([5]) == [(5, 1)]
    assert coalesce_runs([3, 1, 2, 7, 8, 0]) == [(0, 4), (7, 2)]


def test_latency_storage_coalesced_run_accounting():
    inner = ArrayStorage([np.zeros(4, np.float32) for _ in range(64)])
    lat = LatencyStorage(inner, latency_s=5e-3, bandwidth=1e12)
    t0 = time.perf_counter()
    got = lat.read_batch(list(range(16)))          # one contiguous run
    contiguous = time.perf_counter() - t0
    assert lat.coalesced_requests == 1 and lat.batched_reads == 1
    assert len(got) == 16
    t0 = time.perf_counter()
    lat.read_batch(list(range(16, 64, 3)))         # 16 isolated items
    scattered = time.perf_counter() - t0
    assert lat.coalesced_requests == 1 + 16
    assert contiguous < scattered / 3              # 1 seek vs 16 seeks
    assert lat.reads == 32 and lat.cache_hits == 0


def test_latency_storage_read_batch_uses_cache():
    inner = ArrayStorage([np.full(4, i, np.float32) for i in range(8)])
    lat = LatencyStorage(inner, latency_s=1e-4, cache_bytes=10**6)
    lat.read_batch(range(8))
    lat.read_batch(range(8))
    assert lat.cache_hits == 8
    assert lat.coalesced_requests == 1             # second pass: all cached
    np.testing.assert_array_equal(lat.read_batch([3])[0],
                                  np.full(4, 3, np.float32))


# --------------------------------------------------------------------------
# slab arena
# --------------------------------------------------------------------------
def test_arena_recycles_slots_and_reaches_full_hit_rate():
    ds = synthetic_image_dataset(512, 8, seed=0)
    dl = DataLoader(ds, 16, params=FAST.replace(num_workers=2,
                                                prefetch_factor=2),
                    shuffle=False, seed=0)
    stream = dl.stream(to_device=False)
    buffers = set()
    for i in range(24):
        b = next(stream)
        assert isinstance(b, ArenaBatch)
        buffers.add(b["image"].__array_interface__["data"][0])
    arena = dl._stream_arena
    assert arena is not None
    assert arena.allocated <= dl.params.arena_capacity()
    # steady state: every buffer ever yielded came from the fixed slab ring
    assert len(buffers) <= arena.allocated
    # warm up until the lazily-grown ring stops allocating...
    for _ in range(8):
        misses_before = arena.misses
        for _ in range(16):
            next(stream)
        if arena.misses == misses_before:
            break
    # ...then hit rate is 100%: no new slabs, ever
    for _ in range(32):
        next(stream)
    assert arena.misses == misses_before
    assert arena.misses <= dl.params.arena_capacity()  # ring-bounded allocs
    assert arena.hits > 0


def test_arena_batch_valid_until_next_request():
    ds = synthetic_image_dataset(256, 8, seed=0)
    dl = DataLoader(ds, 8, params=FAST.replace(num_workers=0),
                    shuffle=False, seed=0)
    it = dl.host_batches(epoch=0)
    ref = ds.get_batch(dl.sampler.local_indices(0, 0), fast=False)
    b0 = next(it)
    np.testing.assert_array_equal(b0["image"], ref["image"])
    kept = b0["image"]                 # view into the slab ring
    for _ in range(dl.params.arena_capacity() + 1):
        next(it)                       # ring wraps: slab now holds new data
    assert not np.array_equal(kept, np.asarray(ref["image"]))


def test_arena_hot_swap_no_slot_leaked_no_batch_lost():
    """Index accounting (as in test_tuning) through the zero-copy path, plus
    slab accounting: after each drain the arena has every slot back."""
    n, gb = 512, 8

    def transform(a):
        return {"x": a}

    def batch_transform(raw, *, out=None):
        if out is None:
            out = {"x": np.empty(raw.shape, raw.dtype)}
        out["x"][...] = raw
        return out

    transform.batch_aware = True
    transform.batch_variant = batch_transform
    ds = make_index_dataset(n, transform=transform)
    dl = DataLoader(ds, gb, shuffle=False, seed=0,
                    params=FAST.replace(num_workers=2, prefetch_factor=2))
    stream = dl.stream(to_device=False)

    seen = [next(stream)["x"][:, 0].copy() for _ in range(10)]
    dl.apply_params(FAST.replace(num_workers=4, prefetch_factor=3))
    while stream.swaps == 0:
        seen.append(next(stream)["x"][:, 0].copy())
    b1 = len(seen) - 1
    got = sorted(np.concatenate(seen[:b1]).tolist())
    assert got == list(range(b1 * gb))             # no batch lost or duplicated

    arena = dl._stream_arena
    assert arena.allocated <= dl.params.arena_capacity()

    dl.apply_params(FAST.replace(num_workers=1, prefetch_factor=1))
    while stream.swaps == 1:
        seen.append(next(stream)["x"][:, 0].copy())
    b2 = len(seen) - 1
    assert sorted(np.concatenate(seen[:b2]).tolist()) == list(range(b2 * gb))

    # steady state after both swaps: the (shrunk) ring recycles with no new
    # allocations — a leaked slot would either deadlock the small pool above
    # or show up here as fresh misses
    for _ in range(5):
        seen.append(next(stream)["x"][:, 0].copy())
    misses = arena.misses
    for _ in range(10):
        seen.append(next(stream)["x"][:, 0].copy())
    assert arena.misses == misses
    cap_now = FAST.replace(num_workers=1, prefetch_factor=1).arena_capacity()
    assert arena.in_use <= cap_now                 # nothing pinned beyond the ring


def test_abandoned_stream_does_not_strand_arena_slots():
    """Dropping a zero-copy stream mid-epoch and opening a new one must not
    deadlock: the old pool's in-flight slots all return to the shared
    stream arena."""
    ds = synthetic_image_dataset(512, 8, seed=0)
    dl = DataLoader(ds, 16, params=FAST.replace(num_workers=2,
                                                prefetch_factor=2),
                    shuffle=False, seed=0)
    s1 = dl.stream(to_device=False)
    for _ in range(3):
        next(s1)                       # abandon mid-epoch, slots in flight
    s2 = dl.stream(to_device=False)    # closes s1 first
    got = [next(s2) for _ in range(16)]
    assert len(got) == 16
    assert dl._stream_arena.allocated <= dl.params.arena_capacity()


def test_explicit_close_releases_everything():
    ds = synthetic_image_dataset(256, 8, seed=0)
    dl = DataLoader(ds, 16, params=FAST.replace(num_workers=2),
                    shuffle=False, seed=0)
    stream = dl.stream(to_device=True)
    next(stream)
    stream.close()
    deadline = time.perf_counter() + 5.0
    while dl._stream_arena.in_use > 0 and time.perf_counter() < deadline:
        time.sleep(0.01)
    assert dl._stream_arena.in_use == 0


def test_transfer_failure_does_not_leak_slot(monkeypatch):
    import repro.data.prefetcher as P
    arena = SlabArena(capacity=2)
    orig = P.put_global_batch
    boom = {"armed": True}

    def failing_put(batch, sharding=None, **kw):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("transient transfer failure")
        return orig(batch, sharding, **kw)

    monkeypatch.setattr(P, "put_global_batch", failing_put)

    def producer():
        for i in range(3):
            slot = arena.acquire()
            if slot is None:
                slot = arena.adopt({"x": np.full((4,), float(i), np.float32)})
            else:
                slot.arrays["x"][...] = i
            yield ArenaBatch(slot)

    with pytest.raises(RuntimeError, match="transient"):
        list(DevicePrefetcher(producer(), depth=2))
    assert arena.in_use == 0           # the failed batch's slot came back


def test_file_storage_is_picklable():
    import pickle
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        items = [np.arange(4, dtype=np.float32) + i for i in range(3)]
        st = FileStorage.create(root, items)
        st.read_batch([0, 1])          # populate mmap cache
        clone = pickle.loads(pickle.dumps(st))
        np.testing.assert_array_equal(clone.read(2), items[2])
        np.testing.assert_array_equal(clone.read_batch([1])[0], items[1])
        assert clone.item_nbytes(0) == st.item_nbytes(0)


def test_batch_transform_rejects_stale_slab():
    from repro.data.dataset import image_batch_transform
    raw = np.zeros((4, 8, 8, 3), np.uint8)
    stale = {"image": np.empty((4, 8, 8, 3), np.float64),   # wrong dtype
             "label": np.empty((4,), np.int32)}
    got = image_batch_transform(raw, out=stale)
    assert got["image"] is not stale["image"]
    assert got["image"].dtype == np.float32


# --------------------------------------------------------------------------
# ordered delivery
# --------------------------------------------------------------------------
@pytest.mark.parametrize("workers", [2, 4])
def test_ordered_delivery_at_any_worker_count(workers):
    """With ordered=True (the default) delivery matches sampler order even
    when per-batch latency varies wildly across workers."""
    n, gb = 256, 8

    def transform(a):
        time.sleep(0.0005 * (int(a[0]) % 5))   # skewed per-batch cost
        return {"x": a}

    ds = make_index_dataset(n, width=2, transform=transform)
    dl = DataLoader(ds, gb, shuffle=False, seed=0,
                    params=LoaderParams(num_workers=workers, ordered=True))
    got = [int(b["x"][0, 0]) for b in dl.host_batches(epoch=0)]
    assert got == list(range(0, n, gb))


def test_ordered_pool_raises_promptly_when_one_worker_errors():
    """A died worker leaves a sequence hole; the ordered consumer must get
    the error via the sentinel instead of parking batches forever."""
    n, gb = 512, 8

    def transform(a):
        if int(a[0]) == 40:            # one poisoned index-batch
            raise ValueError("poisoned sample")
        return {"x": a}

    ds = make_index_dataset(n, width=2, transform=transform)
    idx = ShardedSampler(n, gb, shuffle=False, seed=0).epoch_iter(0)
    pool = ThreadWorkerPool(ds, idx, num_workers=3, prefetch_factor=2,
                            ordered=True)
    with pytest.raises(ValueError, match="poisoned"):
        list(pool)


def test_zero_copy_pool_retries_transient_and_recovers_slot():
    """A one-shot transient IO error no longer escapes the worker: the
    retry loop (DESIGN.md §10) eats it and the epoch completes whole.
    The errored attempt's arena slot is still recovered."""
    ds = synthetic_image_dataset(256, 8, seed=0)
    calls = {"n": 0}
    orig = ds.storage.read_batch

    def flaky_read_batch(indices):
        calls["n"] += 1
        if calls["n"] == 5:
            raise OSError("transient storage failure")
        return orig(indices)

    ds.storage.read_batch = flaky_read_batch
    dl = DataLoader(ds, 8, params=FAST.replace(num_workers=2), shuffle=False,
                    seed=0)
    pool, _ = dl._pool(dl.sampler.epoch_iter(0), for_stream=True)
    got = list(pool)
    assert len(got) == 256 // 8        # transient fault: nothing lost
    assert dl.fault_stats.read_retries >= 1
    assert len(dl.quarantine) == 0
    for b in got:
        maybe_release(b, owned_only=False)
    arena = dl._stream_arena
    assert arena.in_use == 0           # the errored attempt's slot came back


def test_zero_copy_pool_raises_when_storage_stays_down():
    """A PERSISTENT failure still propagates under the default raise
    policy once retries exhaust — and the worker's slot comes back."""
    ds = synthetic_image_dataset(256, 8, seed=0)

    def dead_read_batch(indices):
        raise OSError("storage down hard")

    ds.storage.read_batch = dead_read_batch
    dl = DataLoader(ds, 8, params=FAST.replace(
        num_workers=2, retry_attempts=1, retry_backoff_s=0.0,
        retry_deadline_s=0.2), shuffle=False, seed=0)
    pool, _ = dl._pool(dl.sampler.epoch_iter(0), for_stream=True)
    with pytest.raises(OSError):
        list(pool)
    arena = dl._stream_arena
    assert arena.in_use <= 1           # the errored worker's slot came back


def test_ordered_straggler_does_not_defeat_backpressure():
    """One slow batch must not let the other workers pull and collate the
    whole epoch into the reordering buffer: pulls are bounded by the
    sequence window (queue depth + workers)."""
    n, gb = 800, 8
    event = threading.Event()

    def transform(a):
        if int(a[0]) == 0:             # straggler on the very first batch
            event.wait(1.5)
        return {"x": a}

    ds = make_index_dataset(n, width=2, transform=transform)
    idx = ShardedSampler(n, gb, shuffle=False, seed=0).epoch_iter(0)
    pool = ThreadWorkerPool(ds, idx, num_workers=4, prefetch_factor=2,
                            ordered=True)
    time.sleep(0.5)                    # let the healthy workers run ahead
    pulled_during_straggle = pool._seq
    event.set()
    got = [int(b["x"][0, 0]) for b in pool]
    assert got == list(range(0, n, gb))
    # window = depth (8) + workers (4); one extra for scheduling slop
    assert pulled_during_straggle <= 8 + 4 + 1


def test_unordered_still_delivers_everything():
    ds = synthetic_image_dataset(128, 8, seed=0)
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=3, ordered=False),
                    shuffle=False, seed=0)
    assert sum(1 for _ in dl.host_batches(epoch=0)) == 16


# --------------------------------------------------------------------------
# process pool backpressure
# --------------------------------------------------------------------------
def test_process_pool_bounds_inflight_and_delivers_all():
    ds = synthetic_image_dataset(128, 8, seed=0)
    pulled = {"n": 0}

    def counting_indices():
        for idx in ShardedSampler(128, 8, shuffle=False, seed=0).epoch_iter(0):
            pulled["n"] += 1
            yield idx

    pool = ProcessWorkerPool(ds, counting_indices(), num_workers=2,
                             prefetch_factor=1)
    consumed = 0
    try:
        for batch in pool:
            consumed += 1
            assert batch["image"].shape == (8, 8, 8, 3)
            # in-flight window: consumed + num_workers * prefetch_factor
            assert pulled["n"] <= consumed + 2 + 1
            time.sleep(0.01)
    finally:
        pool.shutdown()
    assert consumed == 16


def test_process_pool_shutdown_unblocks_task_pump():
    """Abandoning iteration mid-epoch must not hang: terminate() joins the
    task pump, which shutdown() has to wake out of the backpressure
    semaphore first."""
    ds = synthetic_image_dataset(128, 8, seed=0)
    idx = ShardedSampler(128, 8, shuffle=False, seed=0).epoch_iter(0)
    pool = ProcessWorkerPool(ds, idx, num_workers=2, prefetch_factor=1)
    it = iter(pool)
    next(it)                           # pump is now parked at the bound
    t0 = time.perf_counter()
    pool.shutdown()
    assert time.perf_counter() - t0 < 5.0


def test_dataset_ragged_fallback_reads_storage_once():
    """Ragged items: the raw batch already fetched is collated per sample —
    storage must not be charged a second time."""
    items = [np.arange(3 + (i % 2), dtype=np.float32) for i in range(16)]
    st = ArrayStorage(items)
    reads = {"batch": 0, "single": 0}
    orig_rb, orig_r = st.read_batch, st.read

    def counting_rb(indices):
        reads["batch"] += 1
        return orig_rb(indices)

    def counting_r(i):
        reads["single"] += 1
        return orig_r(i)

    st.read_batch, st.read = counting_rb, counting_r

    def transform(a):
        return {"x": np.sum(a, keepdims=True)}

    transform.batch_aware = True
    transform.batch_variant = lambda raw, out=None: {"x": raw.sum(1)}
    ds = Dataset(st, transform=transform)
    got = ds.get_batch(np.arange(8))
    assert reads == {"batch": 1, "single": 0}
    ref = [float(np.sum(items[i])) for i in range(8)]
    np.testing.assert_allclose(got["x"].ravel(), ref)


# --------------------------------------------------------------------------
# simulator coalescing fields
# --------------------------------------------------------------------------
@pytest.mark.parametrize("profile", [cifar10_profile(), coco_profile(80)])
def test_simulator_fast_path_profile_never_slower(profile):
    """Coalesced reads + amortized decode must improve (or preserve) every
    simulated cell, so grid optima under the fast path are unchanged or
    better — the paper-table benchmarks stay valid."""
    mach = MachineProfile()
    legacy_sim = LoaderSimulator(profile, mach)
    fast_sim = LoaderSimulator(profile.with_fast_path(run_len=8.0), mach)
    best_legacy, best_fast = float("inf"), float("inf")
    for k in (1, 2, 4, 8):
        for j in (1, 2, 4):
            a = legacy_sim.simulate(batch_size=64, num_batches=32, nworker=k,
                                    nprefetch=j, check_overflow=False).seconds
            b = fast_sim.simulate(batch_size=64, num_batches=32, nworker=k,
                                  nprefetch=j, check_overflow=False).seconds
            assert b <= a * 1.0001
            best_legacy, best_fast = min(best_legacy, a), min(best_fast, b)
    assert best_fast <= best_legacy


def test_simulator_defaults_are_neutral():
    """coalesced_run_len=1 + vectorized_decode_fixed_s=None is bit-for-bit
    the legacy model (existing paper-grid results are untouched)."""
    p = cifar10_profile()
    assert p.coalesced_run_len == 1.0
    assert p.effective_decode_fixed_s == p.decode_cpu_s_fixed
    fp = p.with_fast_path(run_len=4.0)
    assert fp.coalesced_run_len == 4.0
    assert fp.effective_decode_fixed_s < p.decode_cpu_s_fixed


# --------------------------------------------------------------------------
# device prefetcher: threaded transfer + donate
# --------------------------------------------------------------------------
@pytest.mark.parametrize("threads", [1, 2])
def test_prefetcher_transfer_threads_preserve_order(threads):
    batches = [{"x": np.full((4,), i, np.float32)} for i in range(12)]
    out = list(DevicePrefetcher(iter(batches), depth=3,
                                transfer_threads=threads, donate=True))
    assert len(out) == 12
    for i, b in enumerate(out):
        np.testing.assert_array_equal(np.asarray(b["x"]),
                                      np.full((4,), i, np.float32))


def test_prefetcher_releases_arena_slots():
    arena = SlabArena(capacity=2)
    spec_batch = {"x": np.zeros((4,), np.float32)}

    def producer():
        for i in range(6):
            slot = arena.acquire()
            if slot is None:
                slot = arena.adopt({"x": np.full((4,), float(i), np.float32)})
            else:
                slot.arrays["x"][...] = i
            yield ArenaBatch(slot)

    out = list(DevicePrefetcher(producer(), depth=2, transfer_threads=2))
    assert len(out) == 6
    assert arena.in_use == 0                       # every slot came back
    for i, b in enumerate(out):
        np.testing.assert_array_equal(np.asarray(b["x"]),
                                      np.full((4,), i, np.float32))


# --------------------------------------------------------------------------
# end to end through the device path
# --------------------------------------------------------------------------
def test_zero_copy_stream_to_device_matches_legacy():
    ds = synthetic_image_dataset(128, 8, seed=0)
    mk = lambda p: DataLoader(ds, 16, params=p, shuffle=False, seed=0)
    legacy = iter(mk(LEGACY.replace(num_workers=0)).stream(to_device=True))
    fast = iter(mk(FAST.replace(num_workers=2, transfer_threads=2,
                                donate_transfer=True)).stream(to_device=True))
    for _ in range(8):
        a, b = next(legacy), next(fast)
        np.testing.assert_array_equal(np.asarray(a["image"]),
                                      np.asarray(b["image"]))
