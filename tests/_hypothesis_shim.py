"""Optional-hypothesis shim so the seed suite collects without dev extras.

A bare ``import hypothesis`` at test-module top level turns a missing dev
dependency into a collection *error* that takes the whole module's tests
down.  ``pytest.importorskip`` at module level is no better — it would
skip every test in the module, property-based or not.  This shim keeps
the property tests first-class when hypothesis is installed and collects
them as *skipped* (everything else still runs) when it is not::

    from _hypothesis_shim import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies`` at decoration time:
        any attribute access, call, or chain returns itself."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass  # pragma: no cover

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco
