"""Optional-hypothesis shim so the seed suite collects without dev extras.

A bare ``import hypothesis`` at test-module top level turns a missing dev
dependency into a collection *error* that takes the whole module's tests
down.  When hypothesis IS installed (requirements-dev.txt, so CI), the
real library is used unchanged.  When it is not, a miniature fallback
engine runs instead of skipping: each ``@given`` test executes
``max_examples`` deterministic seeded draws (seeded by the test's own
name, so runs are reproducible and example N is stable across sessions),
and a failing example is re-raised with the drawn arguments in the
message.  The fallback covers the strategy surface this suite actually
uses — ``integers``, ``booleans``, ``sampled_from``, ``tuples``,
``lists``, ``just`` and ``.map``/``.filter`` — no shrinking, no example
database::

    from _hypothesis_shim import given, settings, st
"""
import zlib

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20
    _FILTER_TRIES = 1000

    class _Strategy:
        """A draw function rng -> value with hypothesis-ish combinators."""

        def __init__(self, draw):
            self._draw = draw

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(_FILTER_TRIES):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise AssertionError("filter predicate never satisfied")
            return _Strategy(draw)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            choices = list(seq)
            return _Strategy(lambda rng: choices[rng.randrange(len(choices))])

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def tuples(*ss):
            return _Strategy(lambda rng: tuple(s._draw(rng) for s in ss))

        @staticmethod
        def lists(s, min_size=0, max_size=8):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [s._draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _Strategies()

    def settings(*args, **kwargs):
        max_examples = kwargs.get("max_examples", _DEFAULT_EXAMPLES)

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*gargs, **gkwargs):
        def deco(fn):
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
                # deterministic per-test seed: reruns draw the same examples
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    drawn = tuple(s._draw(rng) for s in gargs)
                    kdrawn = {k: s._draw(rng) for k, s in gkwargs.items()}
                    try:
                        fn(*args, *drawn, **{**kdrawn, **kwargs})
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example {i + 1}/{n}: "
                            f"args={drawn} kwargs={kdrawn}") from e

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            # @settings may be applied outside @given; it then tags the
            # runner, which reads the attribute at call time (above)
            if hasattr(fn, "_max_examples"):
                runner._max_examples = fn._max_examples
            return runner

        return deco
