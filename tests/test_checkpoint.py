"""Checkpointer: atomic async saves, GC, restore, resharded restore."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.arange(4.0)},
            "opt": {"mu": jnp.zeros((8, 16)), "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(12, state, aux={"loader": {"epoch": 1}}, block=True)
    restored, aux = ck.restore(_state(seed=99))
    assert aux["step"] == 12
    assert aux["loader"]["epoch"] == 1
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    for s in (5, 10, 15, 20):
        ck.save(s, _state(), block=True)
    assert ck.latest_step() == 20
    assert ck.all_steps() == [15, 20]


def test_async_save_does_not_block(tmp_path):
    ck = Checkpointer(str(tmp_path))
    big = {"w": jnp.zeros((512, 512))}
    t0 = time.perf_counter()
    ck.save(1, big)            # returns before the file lands
    submit_time = time.perf_counter() - t0
    ck.wait()
    assert ck.latest_step() == 1
    assert submit_time < 5.0


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=5)
    for s in (1, 2, 3):
        ck.save(s, {"v": jnp.float32(s)}, block=True)
    restored, aux = ck.restore({"v": jnp.float32(0)}, step=2)
    assert float(restored["v"]) == 2.0
    assert aux["step"] == 2


def test_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(_state())


def test_atomicity_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _state(), block=True)
    entries = os.listdir(tmp_path)
    assert all(not e.endswith(".tmp") for e in entries)
