"""Checkpointer: atomic async saves, GC, restore, resharded restore."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.arange(4.0)},
            "opt": {"mu": jnp.zeros((8, 16)), "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(12, state, aux={"loader": {"epoch": 1}}, block=True)
    restored, aux = ck.restore(_state(seed=99))
    assert aux["step"] == 12
    assert aux["loader"]["epoch"] == 1
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    for s in (5, 10, 15, 20):
        ck.save(s, _state(), block=True)
    assert ck.latest_step() == 20
    assert ck.all_steps() == [15, 20]


def test_async_save_does_not_block(tmp_path):
    ck = Checkpointer(str(tmp_path))
    big = {"w": jnp.zeros((512, 512))}
    t0 = time.perf_counter()
    ck.save(1, big)            # returns before the file lands
    submit_time = time.perf_counter() - t0
    ck.wait()
    assert ck.latest_step() == 1
    assert submit_time < 5.0


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=5)
    for s in (1, 2, 3):
        ck.save(s, {"v": jnp.float32(s)}, block=True)
    restored, aux = ck.restore({"v": jnp.float32(0)}, step=2)
    assert float(restored["v"]) == 2.0
    assert aux["step"] == 2


def test_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(_state())


def test_atomicity_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _state(), block=True)
    entries = os.listdir(tmp_path)
    assert all(not e.endswith(".tmp") for e in entries)


# ---- loader aux: checkpointing mid-quarantine (DESIGN.md §10) --------------

def _faulty_loader(n, gb, bad):
    from repro.data import (DataLoader, Dataset, FaultyStorage, LoaderParams,
                            StorageFaultSpec)
    from repro.data.storage import ArrayStorage
    items = [np.full((4,), i, np.int32) for i in range(n)]
    ds = Dataset(FaultyStorage(ArrayStorage(items),
                               StorageFaultSpec(corrupt_items=bad)),
                 transform=lambda a: {"x": a})
    # prefetch window of one: the producer cannot run far enough ahead of
    # the checkpoint to quarantine ids the consumed position hasn't seen
    return DataLoader(ds, gb, params=LoaderParams(
        num_workers=1, prefetch_factor=1, on_bad_sample="skip",
        retry_attempts=2, retry_backoff_s=1e-3), shuffle=False, seed=0)


def test_loader_checkpoint_mid_quarantine(tmp_path):
    """A checkpoint taken mid-epoch, after some corrupt samples were
    quarantined, restores the quarantine through the loader aux: the
    resumed stream keeps skipping the same ids without re-probing them,
    and combined coverage is exact (epoch minus quarantine, no dups)."""
    from conftest import flat_indices
    from repro.data.sampler import SamplerState

    n, gb, bad = 64, 8, (3, 17, 58)
    bpe = n // gb
    dl = _faulty_loader(n, gb, bad)
    s = dl.stream(to_device=False)
    try:
        first = [next(s) for _ in range(bpe // 2)]   # sees 3 and 17, not 58
        saved = dl.state_dict()
        # checkpoint the CONSUMED position, like the trainer does (the
        # producer prefetches ahead of the consumer)
        saved["sampler"] = SamplerState.from_absolute(s.position, bpe) \
            .to_dict()
        ck = Checkpointer(str(tmp_path))
        ck.save(s.position, _state(), aux={"loader": saved}, block=True)
    finally:
        s.close()
    assert sorted(dl.quarantine.ids().tolist()) == [3, 17]

    _, aux = Checkpointer(str(tmp_path)).restore(_state(seed=1))
    dl2 = _faulty_loader(n, gb, bad)
    dl2.load_state_dict(aux["loader"])
    assert sorted(dl2.quarantine.ids().tolist()) == [3, 17]
    before = dl2.dataset.storage.corrupt_raised
    s2 = dl2.stream(to_device=False)
    try:
        rest = [next(s2) for _ in range(bpe - bpe // 2)]
    finally:
        s2.close()
    # restored ids were screened up front, never re-read; 58 is fresh
    assert flat_indices(first + rest) == \
        [i for i in range(n) if i not in bad]
    assert sorted(dl2.quarantine.ids().tolist()) == sorted(bad)
    assert dl2.dataset.storage.corrupt_raised == before + 1


def test_loader_checkpoint_pre_fault_loads_clean(tmp_path):
    """Checkpoints written before the fault plane existed have no
    ``quarantine`` key — they load with an empty log, not a KeyError."""
    n, gb = 64, 8
    dl = _faulty_loader(n, gb, (3,))
    saved = dl.state_dict()
    saved.pop("quarantine")
    dl2 = _faulty_loader(n, gb, (3,))
    dl2.load_state_dict(saved)
    assert len(dl2.quarantine) == 0
