"""Distributed machinery: sharding rules, fault tolerance, elastic planning,
collective matmul + multi-device equivalence (subprocess with 8 CPU devs)."""
import subprocess
import sys
import textwrap

import pytest
from _hypothesis_shim import given, settings, st

from repro.distributed.fault_tolerance import (FailureInjector,
                                               HeartbeatRegistry,
                                               StragglerDetector, plan_remesh)


# --------------------------------------------------------------------------
# sharding rules (no devices needed: pure PartitionSpec logic)
# --------------------------------------------------------------------------
def _ctx(shape=(2, 16, 16), axes=("pod", "data", "model")):
    from repro.distributed.sharding_rules import ShardingCtx, TRAIN_RULES

    class FakeMesh:
        def __init__(self):
            self.shape = dict(zip(axes, shape))
    return ShardingCtx(FakeMesh(), TRAIN_RULES)


def test_partition_spec_basic():
    ctx = _ctx()
    p = ctx.partition_spec(("batch", None), (256, 4096))
    assert p == __import__("jax").sharding.PartitionSpec(("pod", "data"))


def test_partition_spec_divisibility_guard():
    ctx = _ctx()
    # vocab 49155 (granite) is not divisible by 16 -> axis dropped
    p = ctx.partition_spec(("vocab", "embed"), (49155, 1536))
    assert p[0] is None
    assert ("vocab", "model", 49155) in [tuple(d) for d in ctx.dropped]


def test_partition_spec_no_axis_reuse():
    ctx = _ctx()
    # both logical axes map to "model": second one must not reuse it
    p = ctx.partition_spec(("mlp", "vocab"), (1024, 1024))
    used = [e for e in p if e is not None]
    flat = []
    for e in used:
        flat.extend(e if isinstance(e, tuple) else [e])
    assert len(flat) == len(set(flat))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8).map(lambda k: 2 ** k),
       st.sampled_from(["vocab", "mlp", "heads", "embed", "batch"]),
       st.integers(1, 3))
def test_partition_spec_always_divides_property(dim_scale, axis, rank):
    """Property: every sharded dim is divisible by its shard count."""
    import numpy as np
    ctx = _ctx()
    dims = tuple(dim_scale * (i + 1) for i in range(rank))
    axes = (axis,) + (None,) * (rank - 1)
    p = ctx.partition_spec(axes, dims)
    entry = p[0] if len(p) > 0 else None
    if entry is not None:
        names = entry if isinstance(entry, tuple) else (entry,)
        shards = int(np.prod([ctx.mesh.shape[n] for n in names]))
        assert dims[0] % shards == 0


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------
def test_heartbeat_detects_dead_host():
    t = [0.0]
    reg = HeartbeatRegistry(timeout_s=10, clock=lambda: t[0])
    reg.beat("a")
    reg.beat("b")
    t[0] = 5.0
    reg.beat("a")
    t[0] = 12.0
    assert reg.dead_hosts() == ["b"]
    assert reg.alive_hosts() == ["a"]


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(window=8, threshold=1.5)
    for _ in range(8):
        for h in ("a", "b", "c", "d"):
            det.record(h, 1.0 if h != "c" else 2.0)
    assert det.stragglers() == ["c"]


def test_straggler_detector_needs_data():
    det = StragglerDetector()
    det.record("a", 1.0)
    assert det.stragglers() == []


def test_elastic_plan_keeps_model_axis():
    plan = plan_remesh(alive_hosts=30, devices_per_host=8, model_axis=16,
                       old_hosts=32, old_global_batch=256, restore_step=100)
    assert plan.feasible
    assert plan.new_data_axis == 15
    assert plan.new_global_batch == 240      # per-replica batch preserved
    bad = plan_remesh(alive_hosts=3, devices_per_host=8, model_axis=16,
                      old_hosts=32, old_global_batch=256, restore_step=100)
    assert not bad.feasible


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 8))
def test_elastic_plan_batch_scaling_property(old_hosts, alive, dphost_pow):
    """Property: per-data-replica batch is invariant under feasible plans."""
    devices_per_host = 2 ** (dphost_pow % 4)
    model_axis = 4
    gb = max(4, old_hosts * devices_per_host // model_axis * 4)
    plan = plan_remesh(alive_hosts=alive, devices_per_host=devices_per_host,
                       model_axis=model_axis, old_hosts=old_hosts,
                       old_global_batch=gb, restore_step=None)
    if plan.feasible:
        old_data = max(1, old_hosts * devices_per_host // model_axis)
        assert abs(plan.new_global_batch / plan.new_data_axis
                   - gb / old_data) < 1.0


def test_failure_injector_schedule():
    inj = FailureInjector({3: ["h1"], 7: ["h2", "h3"]})
    assert inj.advance(1) == []
    assert inj.advance(3) == ["h1"]
    assert inj.advance(7) == ["h2", "h3"]
    assert inj.dead == {"h1", "h2", "h3"}


# --------------------------------------------------------------------------
# multi-device equivalence (subprocess: 8 CPU devices)
# --------------------------------------------------------------------------
def _run_subprocess(code: str):
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
           "REPRO_COMPUTE_DTYPE": "float32",
           "JAX_PLATFORMS": "cpu", "HOME": "/root"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600,
                       cwd="/root/repo", env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_ring_weight_matmul_equals_dot():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.distributed.collective_matmul import ring_weight_matmul
        mesh = jax.make_mesh((4,), ('model',))
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
        with mesh:
            out = ring_weight_matmul(x, w, mesh)
        err = float(jnp.abs(out - jnp.dot(x, w)).max())
        assert err < 1e-4, err
        print('OK', err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_loss_equals_unsharded():
    """The same model code under mesh+rules (with GQA head padding) must
    produce the identical loss as the single-device run."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.models import build_model
        from repro.distributed.sharding_rules import use_rules, rules_for
        for arch in ['qwen2-0.5b', 'granite-moe-3b-a800m', 'mamba2-780m',
                     'hymba-1.5b']:
            cfg = reduced(get_config(arch))
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            rng = np.random.default_rng(0)
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32))
                               .astype(np.int32))
            batch = {'tokens': toks, 'targets': toks,
                     'loss_mask': jnp.ones((8, 32), jnp.float32)}
            ref, _ = m.loss(params, batch, remat_policy='none')
            mesh = jax.make_mesh((2, 4), ('data', 'model'))
            with use_rules(mesh, rules_for('train')):
                sh, _ = jax.jit(lambda p, b: m.loss(
                    p, b, remat_policy='none'))(params, batch)
            d = abs(float(ref) - float(sh))
            assert d < 2e-3, (arch, d)
            print('OK', arch, d)
    """)
    assert out.count("OK") == 4


@pytest.mark.slow
def test_compressed_psum_in_shard_map():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.grad_compress import compressed_psum
        mesh = jax.make_mesh((8,), ('data',))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        err0 = jnp.zeros((8, 64))

        def body(gl, el):
            mean, new_err = compressed_psum(gl[0], el[0], 'data')
            return mean[None], new_err[None]

        with mesh:
            mean, err = shard_map(body, mesh=mesh,
                                  in_specs=(P('data'), P('data')),
                                  out_specs=(P('data'), P('data')))(g, err0)
        true_mean = g.mean(0)
        got = mean[0]
        err_ = float(jnp.abs(got - true_mean).max())
        # int8 channel: error bounded by one quantization bin
        bin_ = float(jnp.abs(g).max()) / 127
        assert err_ <= bin_ + 1e-6, (err_, bin_)
        print('OK', err_)
    """)
    assert "OK" in out
