"""Serving engine: generation correctness and the batching frontend."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve.engine import BatchingFrontend, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, max_batch=4, max_len=64), cfg


def test_greedy_generate_is_deterministic(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    a = eng.generate(prompts, 8)
    b = eng.generate(prompts, 8)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens.shape == (2, 8)


def test_generate_matches_manual_decode_loop(engine):
    """Engine output == hand-rolled prefill + decode_step loop."""
    eng, cfg = engine
    model, params = eng.model, eng.params
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)
    res = eng.generate(prompts, 5)

    cache = model.init_cache(2, eng.max_len)
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompts)},
                                  cache)
    tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    pos = jnp.full((2,), 12, jnp.int32)
    for _ in range(4):
        logits, cache = model.decode_step(params, cache, tok[:, None], pos)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1) \
            .astype(jnp.int32)
        pos = pos + 1
        out.append(np.asarray(tok))
    np.testing.assert_array_equal(res.tokens, np.stack(out, 1))


def test_generated_continuation_consistency(engine):
    """Greedy property: re-prefilling prompt+generated prefix reproduces the
    next generated token (KV cache == full recompute)."""
    eng, cfg = engine
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, (1, 10)).astype(np.int32)
    res = eng.generate(prompt, 6)
    k = 3
    extended = np.concatenate([prompt, res.tokens[:, :k]], axis=1)
    cache = eng.model.init_cache(1, eng.max_len)
    logits, _ = eng.model.prefill(eng.params,
                                  {"tokens": jnp.asarray(extended)}, cache)
    nxt = int(jnp.argmax(logits[0, -1].astype(jnp.float32)))
    assert nxt == int(res.tokens[0, k])


def test_batching_frontend_serves_all_requests(engine):
    eng, cfg = engine
    frontend = BatchingFrontend(eng, max_wait_s=0.02)
    rng = np.random.default_rng(3)
    reqs = [frontend.submit(
        rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32), 4)
        for _ in range(10)]
    outs = [r.result.get(timeout=300) for r in reqs]
    frontend.shutdown()
    assert len(outs) == 10
    assert all(o.shape == (4,) for o in outs)
    assert frontend.batches_served <= 10   # batching actually batched some


def test_temperature_sampling_varies():
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_batch=2, max_len=64,
                      temperature=1.5)
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    a = eng.generate(prompts, 12, seed=0)
    b = eng.generate(prompts, 12, seed=1)
    assert not np.array_equal(a.tokens, b.tokens)


# --------------------------------------------------------------------------
# batch-mix drift monitor (no model needed)
# --------------------------------------------------------------------------
def test_batch_mix_monitor_fires_on_mix_change():
    from repro.serve.engine import BatchMixMonitor
    fired = []
    mon = BatchMixMonitor(window=8, threshold=0.4, cooldown=32,
                          on_drift=fired.append)
    for _ in range(16):
        mon.record((16, 4))         # steady short-prompt traffic
    assert not fired
    for _ in range(16):
        mon.record((512, 64))       # traffic shifts to long prompts
    assert mon.drifts == 1          # fired once, then cooldown holds
    assert fired and (512, 64) in fired[0]


def test_batch_mix_monitor_stable_mix_never_fires():
    from repro.serve.engine import BatchMixMonitor
    mon = BatchMixMonitor(window=8, threshold=0.4, cooldown=0)
    for i in range(64):
        mon.record((16, 4) if i % 2 else (32, 8))
    assert mon.drifts == 0
