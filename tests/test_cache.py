"""Cross-epoch cache tier (DESIGN.md §7): the tier itself, the read-path
adapter, loader integration (hot swap / reshard / trial isolation), the
cache-budget DPT axis through every tuner layer, and the simulator's
hit-ratio x latency-delta pricing of the knob.
"""
import dataclasses
import math

import numpy as np
import pytest

from conftest import (flat_indices, make_cold_dataset, make_index_dataset,
                      make_table_evaluator)

from repro.core.cache import DPTCache
from repro.core.dpt import DPTConfig, DPTResult, Trial
from repro.core.monitor import MemoryOverflow
from repro.core.simulator import LoaderSimulator, MachineProfile
from repro.data import DataLoader, LoaderParams
from repro.data.arena import SlabArena
from repro.data.cache import CachedStorage, CacheTier, plan_hot_chunks
from repro.data.storage import ArrayStorage, StorageProfile
from repro.tuning import cache_win, sweep_cache, tune


def _items(n, width=4):
    return [np.full((width,), i, np.int64) for i in range(n)]  # 32B each


# --------------------------------------------------------------------------
# the plan + the tier
# --------------------------------------------------------------------------
def test_plan_hot_chunks_deterministic_math():
    # 100 items in chunks of 8 -> 13 chunks of 80 bytes each
    assert plan_hot_chunks(0, 8, 100, 10.0) == 0
    assert plan_hot_chunks(79, 8, 100, 10.0) == 0      # < one chunk
    assert plan_hot_chunks(160, 8, 100, 10.0) == 2
    assert plan_hot_chunks(800, 8, 100, 10.0) == 10
    assert plan_hot_chunks(1 << 40, 8, 100, 10.0) == 13  # clamped
    assert plan_hot_chunks(1 << 40, 8, 0, 10.0) == 0
    assert plan_hot_chunks(1 << 40, 8, 100, 0.0) == 0


def test_tier_admits_hot_set_only_within_budget():
    items = _items(64)
    tier = CacheTier(8 * 32, chunk=4, num_items=64, item_nbytes=32.0)
    assert tier.hot_chunks == 2
    for i in range(64):
        assert tier.admit(i, items[i]) == (i < 8)
    assert len(tier) == 8
    assert tier.nbytes_in_use() == tier.budget_bytes
    hits, missing = tier.lookup([0, 7, 8, 63])
    assert sorted(hits) == [0, 7] and missing == [8, 63]
    c = tier.counters()
    assert c["cache_tier_hits"] == 2 and c["cache_tier_misses"] == 2
    assert c["cache_tier_items"] == 8 and c["cache_tier_bytes"] == 8 * 32


def test_tier_reconfigure_is_a_trim_never_a_flush():
    items = _items(64)
    tier = CacheTier(1 << 20, chunk=4, num_items=64, item_nbytes=32.0)
    for i in range(16):
        assert tier.admit(i, items[i])
    # shrink to one hot chunk: chunks 1..3 evicted highest-first, chunk 0
    # stays resident — warm entries survive the resize
    tier.resize(4 * 32)
    assert tier.hot_chunks == 1
    assert len(tier) == 4 and tier.evictions == 12
    hits, missing = tier.lookup([0, 3, 4])
    assert sorted(hits) == [0, 3] and missing == [4]
    assert tier.nbytes_in_use() == 4 * 32
    # re-spec the chunk size: hot set recomputed from the new geometry
    tier.reconfigure(budget_bytes=1 << 20, chunk=8)
    assert tier.hot_chunks == 8
    assert len(tier) == 4                # nothing flushed
    # growing the budget back never resurrects evicted entries by itself
    _, missing = tier.lookup([5])
    assert missing == [5]


def test_tier_budget_shared_with_arena():
    used = [0]
    tier = CacheTier(100, chunk=1, num_items=10, item_nbytes=10.0,
                     arena_bytes=lambda: used[0])
    ten = np.zeros(10, np.uint8)
    assert tier.admit(0, ten)
    used[0] = 95                        # arena pressure eats the budget
    assert not tier.admit(1, ten)
    used[0] = 0
    assert tier.admit(1, ten)
    assert tier.nbytes_in_use() == 20


def test_cached_storage_serves_hits_and_never_rereads():
    storage = ArrayStorage(_items(16))
    tier = CacheTier(1 << 20, chunk=4, num_items=16, item_nbytes=32.0)
    cs = CachedStorage(storage, tier, admit=True)
    assert len(cs) == 16
    first = cs.read_batch(range(16))
    assert [int(a[0]) for a in first] == list(range(16))
    assert (tier.hits, tier.misses) == (0, 16)
    second = cs.read_batch(range(16))
    assert [int(a[0]) for a in second] == list(range(16))
    assert tier.hits == 16
    assert int(cs.read(3)[0]) == 3 and tier.hits == 17
    # a read-only view (trial isolation) never admits
    tier.clear()
    ro = CachedStorage(storage, tier, admit=False)
    ro.read(3)
    ro.read_batch([4, 5])
    assert len(tier) == 0


def test_arena_nbytes_in_use_accounting():
    arena = SlabArena(2)
    assert arena.nbytes_in_use() == 0
    batch = {"x": np.zeros((4, 4), np.float32)}
    slot = arena.adopt(dict(batch))
    assert slot is not None
    nbytes = batch["x"].nbytes
    assert arena.nbytes_in_use() == arena.allocated * nbytes


# --------------------------------------------------------------------------
# loader integration: live stream, hot swap, reshard, trials, counters
# --------------------------------------------------------------------------
def _cached_loader(n=64, gb=16, *, budget=1 << 30, chunk=8, seed=0,
                   **kw):
    return DataLoader(make_index_dataset(n), gb,
                      params=LoaderParams(num_workers=1,
                                          locality_chunk=chunk,
                                          cache_budget_bytes=budget),
                      shuffle=True, seed=seed, **kw)


def test_stream_epoch_two_serves_from_the_tier():
    n, gb = 64, 16
    dl = _cached_loader(n, gb)
    tier = dl.cache_tier
    assert tier is not None and tier.hot_chunks == 8   # everything hot
    s = dl.stream(to_device=False)
    try:
        batches = [next(s) for _ in range(2 * (n // gb))]
    finally:
        s.close()
    # both epochs exact; the warm epoch was served from residency
    assert flat_indices(batches[:n // gb]) == list(range(n))
    assert flat_indices(batches[n // gb:]) == list(range(n))
    assert len(tier) == n
    io = dl.io_counters()
    assert io["cache_tier_hits"] >= n
    assert io["cache_tier_bytes"] == tier.nbytes_in_use()


def test_hot_swap_resizes_the_tier_in_place():
    n, gb = 64, 16
    dl = _cached_loader(n, gb)
    tier = dl.cache_tier
    s = dl.stream(to_device=False)
    try:
        for _ in range(n // gb):
            next(s)
        assert len(tier) > 0
        resident = len(tier)
        # a (workers, prefetch) swap keeps the tier and its contents
        dl.apply_params(dl.params.replace(num_workers=2))
        assert dl.cache_tier is tier
        assert len(tier) >= resident
        # a budget shrink resizes the SAME tier (trim, not flush); the
        # swap commits at the live stream's next drain boundary
        dl.apply_params(dl.params.replace(
            cache_budget_bytes=4 * 8 * 16))      # 4 chunks of 8 x 16B
        for _ in range(4 * (n // gb)):           # stream survives the swap
            next(s)
            if tier.hot_chunks == 4:
                break
        assert dl.cache_tier is tier
        assert tier.hot_chunks == 4
        assert 0 < len(tier) <= 32
    finally:
        s.close()


def test_reshard_respecs_the_tier_not_drops_it():
    n, gb = 96, 24
    dl = _cached_loader(n, gb, seed=1, host_index=0, host_count=2)
    tier = dl.cache_tier
    s = dl.stream(to_device=False)
    try:
        next(s)
        dl.reshard(1, 0)                  # take over the whole batch
        for _ in range(2):
            next(s)
        # the tier keys on ABSOLUTE indices, so a reshard re-specs it
        # (num_items unchanged here) instead of dropping warm entries
        assert dl.cache_tier is tier
    finally:
        s.close()


def test_measure_transfer_time_trial_isolation():
    n, gb = 64, 16
    dl = _cached_loader(n, gb)
    tier = dl.cache_tier
    # B > 0: throwaway tier (prewarmed at epoch >= 1); live tier untouched
    stats = dl.measure_transfer_time(2, epoch=1, to_device=False,
                                     cache_budget_bytes=1 << 20)
    assert stats.cache_hits == 2 * gb          # every trial read hit
    assert len(tier) == 0 and tier.hits == 0
    # 0: bypass — no tier in the trial's read path at all
    stats0 = dl.measure_transfer_time(2, epoch=1, to_device=False,
                                      cache_budget_bytes=0)
    assert stats0.cache_hits == 0
    assert len(tier) == 0
    # None: a read-only view over the LIVE tier — misses never admit
    dl.measure_transfer_time(2, epoch=0, to_device=False)
    assert len(tier) == 0


def test_transfer_stats_split_hits_and_misses_cold_storage():
    n, gb = 48, 12
    dl = DataLoader(make_cold_dataset(n, latency_s=1e-4), gb,
                    params=LoaderParams(num_workers=1, locality_chunk=8),
                    shuffle=True, seed=0)
    cold = dl.measure_transfer_time(4, epoch=0, to_device=False,
                                    cache_budget_bytes=1 << 30)
    assert cold.cache_hits == 0 and cold.cache_misses == n
    warm = dl.measure_transfer_time(4, epoch=1, to_device=False,
                                    cache_budget_bytes=1 << 30)
    assert warm.cache_hits == n and warm.cache_misses == 0


# --------------------------------------------------------------------------
# the cache-budget axis through the tuners
# --------------------------------------------------------------------------
SMALL, BIG = 1 << 16, 1 << 30


def test_sweep_cache_prices_warm_and_cache_win():
    ev = make_table_evaluator(
        lambda i, j, c, b, e: (1.0 - (0.4 if b and e >= 1 else 0.0)
                               + 0.2 * (b == BIG)), cache=True)
    trials = sweep_cache(ev, nworker=4, nprefetch=2,
                         budgets=(0, SMALL, BIG), current_budget=0,
                         num_batches=8)
    assert set(trials) == {0, SMALL, BIG}
    assert all(e == 1 for e in ev.epochs)     # priced at a WARM epoch
    assert all(t.cache_budget_bytes == b for b, t in trials.items())
    assert cache_win(trials, 0) == SMALL
    assert cache_win(trials, SMALL) is None   # best == current: keep
    # an insignificant gap keeps the current budget
    flat = {0: Trial(4, 2, 1.0), SMALL: Trial(4, 2, 0.99)}
    assert cache_win(flat, 0, min_improvement=0.05) is None


def test_grid_search_four_axis_picks_nonzero_budget():
    ev = make_table_evaluator(
        lambda i, j, c, b, e: (4.0 / i + 0.1 * j
                               - (1.0 if b == SMALL and e >= 1 else 0.0)
                               + (0.5 if b == BIG else 0.0)), cache=True)
    cfg = DPTConfig(num_cpu_cores=4, num_devices=2, max_prefetch=2,
                    num_batches=4, epoch=1, cache_budgets=(0, SMALL, BIG))
    res = tune(evaluator=ev, strategy="grid", config=cfg,
               measure_default=False)
    assert (res.nworker, res.nprefetch) == (4, 1)
    assert res.cache_budget_bytes == SMALL
    assert any(t.cache_budget_bytes == SMALL for t in res.trials)
    # the axis unset: the evaluator must never see the kwarg (legacy
    # two-arg evaluators keep working) and the result carries budget 0
    legacy = make_table_evaluator(lambda i, j: 4.0 / i + 0.1 * j)
    res2 = tune(evaluator=legacy, strategy="grid",
                config=dataclasses.replace(cfg, cache_budgets=None),
                measure_default=False)
    assert res2.cache_budget_bytes == 0


def test_dpt_cache_fourth_axis_backcompat_and_clobber_protection():
    cache = DPTCache()
    searched = DPTResult(4, 2, 0.5, [
        Trial(4, 2, 1.0, cache_budget_bytes=0),
        Trial(4, 2, 0.5, cache_budget_bytes=SMALL)],
        cache_budget_bytes=SMALL)
    cache.put("m", "d", 32, searched)
    # the legacy 3-tuple contract is unchanged
    assert cache.get_params("m", "d", 32) == (4, 2, 0)
    assert cache.get_params("m", "d", 32, with_cache=True) \
        == (4, 2, 0, SMALL)
    assert cache.get_params("m", "d", 32, require_cache=True,
                            with_cache=True) == (4, 2, 0, SMALL)
    # a budget-blind refinement must not clobber the searched budget
    blind = DPTResult(6, 1, 0.4, [Trial(6, 1, 0.4)])
    cache.put("m", "d", 32, blind)
    assert cache.get_params("m", "d", 32, with_cache=True) \
        == (6, 1, 0, SMALL)
    # a fresh entry whose search never swept the axis misses require_cache
    cache.put("m2", "d", 32, blind)
    assert cache.get_params("m2", "d", 32, require_cache=True) is None


def test_online_retune_sweeps_and_applies_cache_budget():
    from repro.tuning import OnlineTuner, OnlineTunerConfig
    dl = DataLoader(make_index_dataset(64), 16,
                    params=LoaderParams(num_workers=2, prefetch_factor=1),
                    shuffle=True, seed=0)
    # flat in (workers, prefetch); only a warm cache budget helps
    ev = make_table_evaluator(
        lambda i, j, c, b, e: 1.0 - (0.5 if b == SMALL and e >= 1 else 0.0),
        cache=True)
    tuner = OnlineTuner(dl, evaluator=ev, config=OnlineTunerConfig(
        num_cpu_cores=4, num_devices=2, max_prefetch=2,
        retune_budget_batches=2, cache_budgets=(0, SMALL)))
    params = tuner.force_retune(reason="test")
    assert params is not None
    assert params.cache_budget_bytes == SMALL
    assert dl.params.cache_budget_bytes == SMALL
    assert dl.cache_tier is not None and dl.cache_tier.hot_chunks > 0
    assert tuner.history[-1]["outcome"] == "applied"
    assert tuner.history[-1]["cache_budget_bytes"] == SMALL


def test_fleet_consensus_pushes_uniform_cache_budget():
    from repro.tuning import FleetConfig, FleetCoordinator, HostAgent
    n, gb, hosts = 96, 12, 2
    coord = FleetCoordinator(config=FleetConfig(
        heartbeat_timeout_s=30.0, warmup_steps=1, cooldown_steps=1,
        num_cpu_cores=4, num_devices=2, max_prefetch=2,
        retune_budget_batches=2, cache_budgets=(0, SMALL)))
    agents = []
    for h in range(hosts):
        dl = DataLoader(make_index_dataset(n), gb, shuffle=True, seed=3,
                        params=LoaderParams(num_workers=2,
                                            prefetch_factor=1),
                        host_index=h, host_count=hosts)
        ev = make_table_evaluator(
            lambda i, j, c, b, e: (4.0 / i + 0.1 * j
                                   - (1.0 if b == SMALL and e >= 1
                                      else 0.0)), cache=True)
        agents.append(coord.register(HostAgent(f"host{h}", dl,
                                               evaluator=ev)))
    coord.request_consensus(reason="test")
    coord.poll()
    event = coord.events[-1]
    assert event["kind"] == "consensus" and event["applied"]
    assert event["cache_budget_bytes"] == SMALL
    for a in agents:
        assert a.loader.params.cache_budget_bytes == SMALL
        assert a.loader.cache_tier is not None
    # every host computes the same hot set — no coordination needed
    plans = {a.loader.cache_tier.hot_chunks for a in agents}
    assert len(plans) == 1


def test_fleet_join_copies_cache_plan_and_budget():
    from repro.tuning import FleetConfig, FleetCoordinator, HostAgent
    n, gb = 96, 12
    coord = FleetCoordinator(config=FleetConfig(heartbeat_timeout_s=30.0))

    def spawn(h, count, budget):
        dl = DataLoader(make_index_dataset(n), gb, shuffle=True, seed=5,
                        params=LoaderParams(num_workers=1,
                                            locality_chunk=8,
                                            cache_budget_bytes=budget),
                        host_index=h, host_count=count)
        return HostAgent(f"host{h}", dl,
                         evaluator=make_table_evaluator(lambda i, j: 1.0))

    incumbents = [coord.register(spawn(h, 2, SMALL)) for h in range(2)]
    joiner = spawn(2, 1, 0)
    coord.join(joiner)
    src = incumbents[0].loader
    assert joiner.loader.params.cache_budget_bytes == SMALL
    assert joiner.loader.sampler.cache_state() == src.sampler.cache_state()
    assert joiner.loader.cache_tier is not None
    assert joiner.loader.cache_tier.hot_chunks \
        == src.cache_tier.hot_chunks


# --------------------------------------------------------------------------
# the simulator's pricing of the axis
# --------------------------------------------------------------------------
_SP = StorageProfile(num_items=10_000, item_bytes=1e5,
                     decoded_item_bytes=4e5, io_latency_s=5e-3,
                     seek_congestion=0.2, storage_bw=80e6,
                     decode_cpu_s_fixed=100e-6, decode_cpu_s_per_byte=2e-9)
# RAM-constrained host whose page cache is unreliable under pressure —
# the regime where an explicitly pinned tier earns its footprint
_MP_TIGHT = MachineProfile(host_ram=8e9, page_cache_eff=0.2,
                           worker_overhead_bytes=0.2e9)


def test_simulator_neutral_default_is_bit_identical():
    sim = LoaderSimulator(_SP, MachineProfile())
    kw = dict(batch_size=32, num_batches=16, nworker=4, nprefetch=2,
              epoch=1)
    assert sim.simulate(**kw) == sim.simulate(**kw, cache_budget_bytes=0)


def test_simulator_prices_budget_as_hit_ratio_vs_footprint():
    sim = LoaderSimulator(_SP, _MP_TIGHT)
    kw = dict(batch_size=32, num_batches=16, nworker=4, nprefetch=2)
    # warm epoch: the pinned tier's certain hits beat the leaky page cache
    no_budget = sim.simulate(**kw, epoch=1)
    budget = sim.simulate(**kw, epoch=1, cache_budget_bytes=1e9)
    assert budget.warm_fraction > no_budget.warm_fraction
    assert budget.seconds < no_budget.seconds
    # cold epoch: the budget only costs footprint, never buys time
    cold0 = sim.simulate(**kw, epoch=0)
    cold1 = sim.simulate(**kw, epoch=0, cache_budget_bytes=1e9)
    assert cold1.seconds == cold0.seconds
    assert cold1.peak_bytes > cold0.peak_bytes
    # a budget past the RAM line overflows like any other footprint
    with pytest.raises(MemoryOverflow):
        sim.simulate(**kw, epoch=1, cache_budget_bytes=10e9)


def test_simulated_grid_picks_budget_warm_and_zero_cold():
    from repro.core.evaluators import SimulatorEvaluator
    ev = SimulatorEvaluator(LoaderSimulator(_SP, _MP_TIGHT), batch_size=32)
    cfg = DPTConfig(num_cpu_cores=4, num_devices=2, max_prefetch=2,
                    num_batches=8, epoch=1, cache_budgets=(0, int(1e9)))
    warm = tune(evaluator=ev, strategy="grid", config=cfg,
                measure_default=False)
    assert warm.cache_budget_bytes == int(1e9)
    cold = tune(evaluator=ev, strategy="grid",
                config=dataclasses.replace(cfg, epoch=0),
                measure_default=False)
    assert cold.cache_budget_bytes == 0       # ties resolve to no cache


# --------------------------------------------------------------------------
# trainer plumbing
# --------------------------------------------------------------------------
def test_trainer_guards_cache_axis_like_locality():
    from repro.train.trainer import TrainerConfig
    cfg = TrainerConfig(autotune_cache_budgets=(0, SMALL))
    assert cfg.autotune_cache_budgets == (0, SMALL)
    # the online tuner inherits the axis on a single host
    from repro.train.trainer import Trainer
    dl = DataLoader(make_index_dataset(32), 8, shuffle=True, seed=0,
                    params=LoaderParams(num_workers=1))
    t = Trainer.__new__(Trainer)
    t.loader, t.cfg = dl, cfg
    tuner = t._make_online_tuner()
    assert tuner.cfg.cache_budgets == (0, SMALL)
    # sharded: the axis must stay off host-local retunes
    dl2 = DataLoader(make_index_dataset(32), 8, shuffle=True, seed=0,
                     params=LoaderParams(num_workers=1),
                     host_index=0, host_count=2)
    t.loader = dl2
    assert t._make_online_tuner().cfg.cache_budgets is None
