"""End-to-end behaviour tests: DPT-tuned training on a latency-injected
storage, restart-after-crash, and the full serve path — the system acting
as the paper + framework promises."""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.monitor import MemoryBudget
from repro.data import (DataLoader, Dataset, LatencyStorage, LoaderParams,
                        token_dataset)
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainStepConfig
from repro.train.trainer import Trainer, TrainerConfig


def test_end_to_end_dpt_tuned_training(tmp_path):
    """The headline integration: loader tuned by DPT (real wall-clock
    measurements on latency-injected storage) feeding a real train loop,
    with checkpointing; loss decreases and tuned params beat 0 workers."""
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)

    base = token_dataset(96, 16, cfg.vocab_size, seed=0)
    lat = LatencyStorage(base.storage, latency_s=1e-3, bandwidth=1e9)
    ds = Dataset(lat, transform=base.transform)
    dl = DataLoader(ds, 8, seed=0)

    tc = TrainerConfig(
        total_steps=36, checkpoint_every=18, log_every=6,
        checkpoint_dir=str(tmp_path / "ckpt"),
        autotune=True, autotune_budget_batches=4, autotune_max_prefetch=2,
        dpt_cache_path=str(tmp_path / "dpt.json"),
        step_config=TrainStepConfig(
            remat_policy="none",
            optimizer=AdamWConfig(peak_lr=3e-3, warmup_steps=2,
                                  total_steps=36)))
    tr = Trainer(model, dl, tc)
    out = tr.run()
    assert out["final_step"] == 36
    assert out["loss"] < 5.4   # memorizing the 96-item set (ln(256)=5.545 at init)
    assert dl.params.num_workers >= 1  # DPT chose parallel loading

    # crash-restart: a new trainer resumes from the checkpoint
    dl2 = DataLoader(ds, 8, seed=0)
    tr2 = Trainer(model, dl2, tc)
    tr2._maybe_restore()
    assert tr2.start_step == 36


def test_serve_end_to_end():
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.serve.engine import ServeEngine
    eng = ServeEngine(model, params, max_batch=2, max_len=48)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    res = eng.generate(prompts, 8)
    assert res.tokens.shape == (2, 8)
    assert res.tokens_per_second > 0


def test_launchers_run(tmp_path):
    """The CLI entry points work end to end (reduced configs)."""
    import subprocess, sys, json
    env = dict(os.environ, PYTHONPATH="src", REPRO_COMPUTE_DTYPE="float32",
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mamba2-780m",
         "--reduced", "--steps", "6", "--global-batch", "4",
         "--seq-len", "32", "--no-autotune",
         "--checkpoint-dir", str(tmp_path / "ck")],
        capture_output=True, text=True, timeout=600, cwd="/root/repo",
        env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["final_step"] == 6

    r2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2-0.5b",
         "--reduced", "--requests", "4", "--prompt-len", "8",
         "--max-new", "4", "--max-batch", "2"],
        capture_output=True, text=True, timeout=600, cwd="/root/repo",
        env=env)
    assert r2.returncode == 0, r2.stderr[-2000:]
    out2 = json.loads(r2.stdout.strip().splitlines()[-1])
    assert out2["requests"] == 4
