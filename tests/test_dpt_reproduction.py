"""Validation of the paper's empirical claims against our calibrated
simulator — the reproduction gate (EXPERIMENTS.md §Repro cites these)."""
import math

import pytest

from repro.core import (DPT, DPTConfig, LoaderSimulator, MachineProfile,
                        MemoryOverflow, SimulatorEvaluator)
from repro.data.storage import cifar10_profile, coco_profile

MACHINE = MachineProfile()     # the paper's i7-8700K / 64GB / 1 GPU testbed


def run_dpt(profile, batch, epoch, max_prefetch=8, num_batches=64,
            device_ram=None):
    sim = LoaderSimulator(profile, MACHINE)
    ev = SimulatorEvaluator(sim, batch_size=batch, device_ram=device_ram)
    cfg = DPTConfig(num_cpu_cores=12, num_devices=1,
                    max_prefetch=max_prefetch, num_batches=num_batches,
                    epoch=epoch)
    return DPT(ev, cfg).run(), ev


def test_cifar_optimal_workers_is_ten_ish():
    """Paper Fig 2a: optimum at 10 workers (12 logical cores - main/loader),
    NOT the PyTorch default of 6."""
    res, _ = run_dpt(cifar10_profile(), 32, epoch=1)
    assert 9 <= res.nworker <= 11
    assert res.nworker != 6


def test_cifar_speedup_over_default():
    """Paper Fig 2a: ~1.3x over PyTorch defaults."""
    res, _ = run_dpt(cifar10_profile(), 32, epoch=1)
    assert 1.15 <= res.speedup_vs_default <= 1.6


def test_small_resolution_speedups_match_table1d():
    """Paper Table 1d, 80x80: 1.17-1.37x."""
    for epoch in (0, 1):
        res, _ = run_dpt(coco_profile(80), 32, epoch=epoch)
        assert res.speedup_vs_default >= 1.10, (epoch, res.speedup_vs_default)


def test_large_resolution_is_flat():
    """Paper Table 1d, 640x640 1st epoch: ~1.0x (bandwidth-bound: grid is
    flat, tuning cannot help much)."""
    res, _ = run_dpt(coco_profile(640), 16, epoch=0)
    assert res.speedup_vs_default <= 1.20


def test_cold_epoch_optimum_shifts_down_for_large_items():
    """Paper Table 1a: 1st-epoch optima drop to 5-6 workers at >=320px
    while 80px stays at ~10 (storage bandwidth saturates)."""
    res_small, _ = run_dpt(coco_profile(80), 16, epoch=0)
    res_large, _ = run_dpt(coco_profile(640), 16, epoch=0)
    assert res_large.nworker < res_small.nworker


def test_warm_epoch_much_faster_than_cold():
    """Paper Table 1b: 80x80 drops from ~405s (cold) to ~8s (warm, page
    cache).  Check the ratio regime on full epochs."""
    _, ev = run_dpt(coco_profile(80), 32, epoch=0)
    cold = ev.epoch_seconds(10, 2, epoch=0)
    warm = ev.epoch_seconds(10, 2, epoch=1)
    assert cold / warm > 10


def test_epoch_magnitudes_match_paper_order():
    """Full-epoch seconds at tuned params should land in the paper's
    decade: 80px cold ~400s, 80px warm ~8s, 640px cold ~1300s."""
    _, ev80 = run_dpt(coco_profile(80), 16, epoch=0)
    _, ev640 = run_dpt(coco_profile(640), 16, epoch=0)
    cold80 = ev80.epoch_seconds(10, 3, epoch=0)
    warm80 = ev80.epoch_seconds(10, 3, epoch=1)
    cold640 = ev640.epoch_seconds(6, 3, epoch=0)
    assert 200 < cold80 < 800, cold80          # paper: 396-412
    assert 4 < warm80 < 25, warm80             # paper: 4.3-8.7
    assert 700 < cold640 < 2600, cold640       # paper: 1275-1305


def test_memory_overflow_cell_matches_paper_na():
    """Paper Table 1: 640x640 @ batch 1024 could not execute (GPU 12GB)."""
    sim = LoaderSimulator(coco_profile(640), MACHINE)
    ev = SimulatorEvaluator(sim, batch_size=1024, device_ram=12e9)
    with pytest.raises(MemoryOverflow):
        ev(2, 1, num_batches=4)
    # but batch 128 at the same resolution is fine
    ev2 = SimulatorEvaluator(sim, batch_size=128, device_ram=12e9)
    assert math.isfinite(ev2(2, 1, num_batches=4).seconds)


def test_prefetch_factor_matters_but_less_than_workers():
    """Paper Fig 2b/3: prefetch fluctuations are small vs worker gains."""
    sim = LoaderSimulator(cifar10_profile(), MACHINE)
    ev = SimulatorEvaluator(sim, batch_size=32)
    t_workers = [ev(w, 2, num_batches=64, epoch=1).seconds
                 for w in (2, 10)]
    t_prefetch = [ev(10, j, num_batches=64, epoch=1).seconds
                  for j in (1, 6)]
    worker_gain = t_workers[0] / t_workers[1]
    prefetch_gain = max(t_prefetch) / min(t_prefetch)
    assert worker_gain > prefetch_gain
    assert prefetch_gain > 1.0      # but it is NOT zero -> must be searched
