"""Per-architecture smoke tests (reduced configs, CPU): one train step and
a prefill->decode consistency check for every assigned arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (SHAPES, applicable_shapes, get_config,
                           list_configs, reduced)
from repro.models import build_model

ALL_ARCHS = list_configs()


def make_batch(cfg, B=2, S=24, seed=0, with_targets=True):
    rng = np.random.default_rng(seed)
    text = S - (cfg.num_patches or 0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, text)).astype(np.int32))}
    if with_targets:
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, text)).astype(np.int32))
        batch["loss_mask"] = jnp.ones((B, text), jnp.float32)
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_patches, cfg.patch_embed_dim))
        ).astype(jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.max_source_positions, cfg.d_model))
        ).astype(jnp.float32)
    return batch


def test_all_ten_archs_registered():
    assert len(ALL_ARCHS) == 10
    assert set(ALL_ARCHS) == {
        "yi-34b", "qwen2-0.5b", "mistral-large-123b", "qwen3-1.7b",
        "granite-moe-3b-a800m", "mixtral-8x22b", "mamba2-780m",
        "phi-3-vision-4.2b", "whisper-large-v3", "hymba-1.5b"}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_loss(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = model.loss(params, batch, remat_policy="none")
    assert jnp.isfinite(loss), arch
    assert 3.0 < float(loss) < 8.0   # ~ln(vocab) at random init


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    from repro.train.train_step import (TrainStepConfig, init_train_state,
                                        make_train_step)
    from repro.train.optimizer import AdamWConfig

    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    scfg = TrainStepConfig(remat_policy="none",
                           optimizer=AdamWConfig(peak_lr=1e-3,
                                                 warmup_steps=1,
                                                 total_steps=4))
    state = init_train_state(model, jax.random.PRNGKey(0), scfg)
    step = jax.jit(make_train_step(model, scfg))
    batch = make_batch(cfg)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert jnp.isfinite(m2["loss"])
    assert float(m2["loss"]) < float(m1["loss"])   # same batch -> improves
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_matches_prefill(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = make_batch(cfg, B=B, S=S, with_targets=False)

    logits_full, _ = model.prefill(params, batch, model.init_cache(B, S + 8))

    tokens = batch["tokens"]
    part = dict(batch)
    part["tokens"] = tokens[:, :-1]
    cache = model.init_cache(B, S + 8)
    _, cache = model.prefill(params, part, cache)
    pos = jnp.full((B,), tokens.shape[1] - 1, jnp.int32)
    logits_step, _ = model.decode_step(params, cache, tokens[:, -1:], pos)

    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_step[:, 0], np.float32)
    np.testing.assert_allclose(a, b, atol=5e-3 * max(1, np.abs(a).max()),
                               rtol=1e-2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_microbatched_grads_match_full_batch(arch):
    """Gradient accumulation must equal the full-batch gradient."""
    from repro.train.train_step import TrainStepConfig, make_train_step, \
        init_train_state

    cfg = reduced(get_config(arch))
    if cfg.family == "moe":
        pytest.skip("MoE capacity depends on token count; not bitwise equal")
    model = build_model(cfg)
    batch = make_batch(cfg, B=4, S=16)

    def loss_only(params):
        return model.loss(params, batch, remat_policy="none")[0]

    params = model.init(jax.random.PRNGKey(0))
    g_full = jax.grad(loss_only)(params)

    def loss_mb(params, mb):
        return model.loss(params, mb, remat_policy="none")[0]

    mbs = jax.tree_util.tree_map(
        lambda x: x.reshape(2, 2, *x.shape[1:]), batch)
    g_acc = None
    for i in range(2):
        mb = jax.tree_util.tree_map(lambda x: x[i], mbs)
        g = jax.grad(loss_mb)(params, mb)
        g_acc = g if g_acc is None else jax.tree_util.tree_map(
            jnp.add, g_acc, g)
    g_acc = jax.tree_util.tree_map(lambda x: x / 2, g_acc)

    flat_full = jax.tree_util.tree_leaves(g_full)
    flat_acc = jax.tree_util.tree_leaves(g_acc)
    for a, b in zip(flat_full, flat_acc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)


def test_long_context_applicability_matches_design():
    """long_500k runs exactly for the sub-quadratic archs (DESIGN.md)."""
    expect_long = {"mamba2-780m", "hymba-1.5b", "mixtral-8x22b"}
    got = {a for a in ALL_ARCHS
           if any(s.name == "long_500k"
                  for s in applicable_shapes(get_config(a)))}
    assert got == expect_long


def test_param_counts_in_expected_range():
    """Analytic N should land near the published sizes."""
    expected = {
        "yi-34b": (30e9, 40e9),
        "qwen2-0.5b": (0.4e9, 0.65e9),
        "mistral-large-123b": (110e9, 130e9),
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "granite-moe-3b-a800m": (2.5e9, 4.0e9),
        "mixtral-8x22b": (130e9, 150e9),   # total incl. all experts
        "mamba2-780m": (0.6e9, 1.0e9),
        "phi-3-vision-4.2b": (3.3e9, 4.5e9),   # backbone only (stub frontend)
        "whisper-large-v3": (1.2e9, 1.9e9),
        "hymba-1.5b": (1.2e9, 2.0e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # MoE active params much smaller than total
    mix = get_config("mixtral-8x22b")
    assert mix.active_param_count() < 0.45 * mix.param_count()


def test_hymba_meta_tokens_change_output():
    cfg = reduced(get_config("hymba-1.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, with_targets=False)
    logits, _ = model.prefill(params, batch, model.init_cache(2, 40))
    params2 = dict(params)
    params2["meta_tokens"] = params["meta_tokens"] + 1.0
    logits2, _ = model.prefill(params2, batch, model.init_cache(2, 40))
    assert float(jnp.abs(logits - logits2).max()) > 1e-4


def test_vlm_patches_affect_text_logits():
    cfg = reduced(get_config("phi-3-vision-4.2b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, with_targets=False)
    l1, _ = model.prefill(params, batch, model.init_cache(2, 40))
    batch2 = dict(batch)
    batch2["patch_embeds"] = batch["patch_embeds"] + 5.0
    l2, _ = model.prefill(params, batch2, model.init_cache(2, 40))
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_whisper_encoder_affects_decoder():
    cfg = reduced(get_config("whisper-large-v3"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, with_targets=False)
    l1, _ = model.prefill(params, batch, model.init_cache(2, 40))
    batch2 = dict(batch)
    batch2["frames"] = batch["frames"] * 2.0 + 1.0
    l2, _ = model.prefill(params, batch2, model.init_cache(2, 40))
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_mixtral_sliding_window_masks_distant_tokens():
    """Stacked SWA has receptive field L*(window-1); beyond that a token
    perturbation must not reach the output."""
    cfg = reduced(get_config("mixtral-8x22b"))   # 2 layers, window 16
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 48
    field = cfg.num_layers * (cfg.sliding_window - 1)   # 30
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    l1, _ = model.prefill(params, {"tokens": toks}, model.init_cache(B, S + 4))
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab_size)
    l2, _ = model.prefill(params, {"tokens": toks2},
                          model.init_cache(B, S + 4))
    # last position (47) is > receptive field (30) from token 0
    assert S - 1 > field
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-5)
