import os
import sys

# src layout import path (tests also run without `pip install -e .`)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Correctness suite: fp32 compute for deterministic comparisons.  Must be
# set before any repro.models import.  (The dry-run/benchmarks use bf16.)
os.environ.setdefault("REPRO_COMPUTE_DTYPE", "float32")
