import json
import os
import sys

# src layout import path (tests also run without `pip install -e .`)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Correctness suite: fp32 compute for deterministic comparisons.  Must be
# set before any repro.models import.  (The dry-run/benchmarks use bf16.)
os.environ.setdefault("REPRO_COMPUTE_DTYPE", "float32")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


# --------------------------------------------------------------------------
# shared pipeline scaffolding (deduped from test_fastpath / test_locality /
# test_fleet, which each used to re-implement these builders)
# --------------------------------------------------------------------------
def make_index_dataset(n, *, width=4, transform=None):
    """Dataset whose sample VALUES are their indices — delivered batches
    can be audited for exact coverage (see ``flat_indices``).  A custom
    ``transform`` (fault injection, skewed per-batch cost, ...) receives
    the raw ``(width,)`` index array."""
    from repro.data import Dataset
    from repro.data.storage import ArrayStorage
    items = [np.full((width,), i, np.int32) for i in range(n)]
    return Dataset(ArrayStorage(items),
                   transform=transform or (lambda a: {"x": a}))


def flat_indices(batches):
    """Sorted sample indices recovered from index-dataset batches."""
    return sorted(np.concatenate(
        [np.asarray(b["x"])[:, 0] for b in batches]).tolist())


def make_cold_dataset(n, *, latency_s=1e-3, cache_bytes=0, bandwidth=1e9,
                      item_shape=(8, 8, 3), tail_fraction=0.0,
                      tail_mult=1.0, tail_seed=0, tail_mode="bimodal",
                      fault_rate=0.0, fault_seed=0, brownout=None):
    """Seek-bound cold storage: every miss pays a base latency, which is
    what makes coalesced (chunked-order) reads measurably faster.  The
    tail knobs plant deterministic stragglers (DESIGN.md §9): a seeded
    ``tail_fraction`` of items costs ``tail_mult``x extra on every miss.
    The fault knobs (DESIGN.md §10) inject seeded transient read errors
    and a timed brownout window on the same splitmix64 hashing."""
    from repro.data import ArrayStorage, Dataset, LatencyStorage
    from repro.data.dataset import image_transform
    rng = np.random.default_rng(0)
    items = [rng.integers(0, 255, item_shape, dtype=np.uint8)
             for _ in range(n)]
    storage = LatencyStorage(ArrayStorage(items), latency_s=latency_s,
                             bandwidth=bandwidth, cache_bytes=cache_bytes,
                             tail_fraction=tail_fraction,
                             tail_mult=tail_mult, tail_seed=tail_seed,
                             tail_mode=tail_mode, fault_rate=fault_rate,
                             fault_seed=fault_seed, brownout=brownout)
    return Dataset(storage, transform=image_transform)


def make_table_evaluator(fn, *, locality=False, cache=False):
    """Synthetic evaluator over a (nworker, nprefetch[, chunk]) table;
    records call count and per-call budgets like the real ones.  The
    ``cache`` variant takes the full 4-axis cell plus the epoch —
    ``fn(i, j, chunk, budget, epoch)`` — so tests can price the cache
    axis warm vs cold."""
    from repro.data.loader import TransferStats

    if cache:
        def ev(i, j, *, num_batches=16, epoch=0, locality_chunk=None,
               cache_budget_bytes=None):
            ev.calls += 1
            ev.budgets.append(num_batches)
            ev.epochs.append(epoch)
            return TransferStats(fn(i, j, locality_chunk or 0,
                                    cache_budget_bytes or 0, epoch),
                                 num_batches, 0)
    elif locality:
        def ev(i, j, *, num_batches=16, epoch=0, locality_chunk=None):
            ev.calls += 1
            ev.budgets.append(num_batches)
            return TransferStats(fn(i, j, locality_chunk or 0),
                                 num_batches, 0)
    else:
        def ev(i, j, *, num_batches=16, epoch=0):
            ev.calls += 1
            ev.budgets.append(num_batches)
            return TransferStats(fn(i, j), num_batches, 0)
    ev.calls = 0
    ev.budgets = []
    ev.epochs = []
    return ev


@pytest.fixture
def index_dataset():
    return make_index_dataset


@pytest.fixture
def cold_dataset():
    return make_cold_dataset


@pytest.fixture
def table_evaluator():
    return make_table_evaluator


class FleetHarness:
    """A live in-process fleet: coordinator + one HostAgent/loader/stream
    per host, driven by a fake clock.  Streams the factory handed out are
    closed at teardown even when a test bails early."""

    def __init__(self, coord, agents, streams, clock):
        self.coord = coord
        self.agents = agents
        self.streams = streams
        self.clock = clock

    def tick(self, dt=1.0):
        self.clock[0] += dt

    def close(self):
        for s in self.streams:
            try:
                s.close()
            except Exception:
                pass


@pytest.fixture
def fleet_factory():
    """Factory for a live fleet harness (see ``FleetHarness``)."""
    from repro.data import DataLoader, LoaderParams
    from repro.tuning import FleetConfig, FleetCoordinator, HostAgent

    harnesses = []

    def build(n=480, gb=12, hosts=3, *, timeout=5.0, seed=5,
              evaluator_fn=lambda i, j: 4.0 / i + 0.1 * j,
              config=None, **cfg_kw):
        clock = [0.0]
        defaults = dict(heartbeat_timeout_s=timeout, warmup_steps=2,
                        cooldown_steps=4, num_cpu_cores=4, num_devices=1,
                        max_prefetch=2, retune_budget_batches=2)
        defaults.update(cfg_kw)
        cfg = config or FleetConfig(**defaults)
        coord = FleetCoordinator(config=cfg, clock=lambda: clock[0])
        agents, streams = [], []
        for h in range(hosts):
            dl = DataLoader(make_index_dataset(n), gb, shuffle=True,
                            seed=seed,
                            params=LoaderParams(num_workers=2,
                                                prefetch_factor=2),
                            host_index=h, host_count=hosts)
            agent = coord.register(HostAgent(
                f"host{h}", dl,
                evaluator=make_table_evaluator(evaluator_fn)))
            agents.append(agent)
            streams.append(dl.stream(to_device=False))
        harness = FleetHarness(coord, agents, streams, clock)
        harnesses.append(harness)
        return harness

    yield build
    for h in harnesses:
        h.close()


class WireFleet:
    """A transport-mode fleet (DESIGN.md §8): hosts talk to the
    coordinator over a (fault-injectable) message transport, a lease +
    snapshot store back a standby replica, and a fake clock drives
    heartbeats, lease expiry, and failover deterministically.

    ``rounds`` is one lockstep driver step: every alive host pulls one
    batch and observes (reports cross the wire or park in the link's
    bounded queue), delayed messages pump, the leader ticks its lease and
    polls, and the standby watches for expiry — promotion swaps
    ``self.server``/``self.coord`` to the new leader transparently.
    """

    def __init__(self, *, hosts=3, n=480, gb=12, faults=None, ttl=4.0,
                 heartbeat_timeout=6.0, link_config=None, **cfg_kw):
        from repro.data import DataLoader, LoaderParams
        from repro.tuning import (FaultSpec, FaultyTransport, FleetConfig,
                                  FleetCoordinator, LeaderLease, LinkConfig,
                                  SnapshotStore, connect_host)
        from repro.tuning.fleet import CoordinatorReplica, CoordinatorServer

        self.n, self.gb = n, gb
        self.bpe = n // gb
        self.clock = [0.0]
        ck = lambda: self.clock[0]  # noqa: E731
        self.transport = FaultyTransport(faults or FaultSpec())
        self.lease = LeaderLease(ttl_s=ttl, clock=ck)
        self.store = SnapshotStore()
        defaults = dict(heartbeat_timeout_s=heartbeat_timeout,
                        warmup_steps=2, cooldown_steps=4, num_cpu_cores=4,
                        num_devices=1, max_prefetch=2,
                        retune_budget_batches=2)
        defaults.update(cfg_kw)
        self.coord = FleetCoordinator(config=FleetConfig(**defaults),
                                      clock=ck)
        self.server = CoordinatorServer(self.coord, self.transport,
                                        owner="coord-0", lease=self.lease,
                                        store=self.store)
        self.replica = CoordinatorReplica(self.transport, self.lease,
                                          self.store, owner="coord-standby",
                                          clock=ck)
        self.agents, self.streams = [], []
        for h in range(hosts):
            dl = DataLoader(make_index_dataset(n), gb, shuffle=True, seed=5,
                            params=LoaderParams(num_workers=2,
                                                prefetch_factor=2),
                            host_index=h, host_count=hosts)
            self.agents.append(connect_host(
                self.transport, f"host{h}", dl,
                evaluator=make_table_evaluator(
                    lambda i, j: 4.0 / i + 0.1 * j),
                clock=ck,
                link_config=link_config or LinkConfig(seed=h, jitter=0.0)))
            self.streams.append(dl.stream(to_device=False))
        # deliver any setup message a delay fault parked (a stale register
        # replayed mid-run would be a different, rarer anomaly)
        self.transport.pump()
        self.delivered = []

    def rounds(self, k, alive=None, *, poll=True):
        alive = list(alive if alive is not None else range(len(self.agents)))
        for _ in range(k):
            self.clock[0] += 1.0
            for h in alive:
                self.delivered.append(next(self.streams[h]))
                self.agents[h].observe(data_s=0.001, step_s=0.05)
            self.transport.pump()
            self.server.tick()
            if poll:
                self.server.poll()
            promoted = self.replica.tick()
            if promoted is not None:
                self.server = promoted
                self.coord = promoted.coord

    def drain(self, alive):
        for h in alive:
            s = self.streams[h]
            while s.position < self.bpe:
                self.delivered.append(next(s))

    def close(self):
        for s in self.streams:
            try:
                s.close()
            except Exception:
                pass


@pytest.fixture
def wire_fleet():
    """Factory fixture for :class:`WireFleet`; streams close at teardown."""
    fleets = []

    def build(**kw):
        f = WireFleet(**kw)
        fleets.append(f)
        return f

    yield build
    for f in fleets:
        f.close()


# --------------------------------------------------------------------------
# per-test duration accounting (CI budget gate, see check_durations.py)
# --------------------------------------------------------------------------
_durations = {}


def pytest_runtest_logreport(report):
    if report.when == "call":
        _durations[report.nodeid] = report.duration


def pytest_sessionfinish(session, exitstatus):
    path = os.environ.get("REPRO_DURATIONS_JSON")
    if path and _durations:
        with open(path, "w") as f:
            json.dump({k: round(v, 3) for k, v in _durations.items()},
                      f, indent=1, sort_keys=True)
