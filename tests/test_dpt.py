"""DPT Algorithm 1 semantics + beyond-paper search strategies."""
import math

import pytest
from _hypothesis_shim import given, settings, st

from repro.core import (DPT, DPTConfig, LoaderSimulator, MachineProfile,
                        MemoryOverflow, MultiHostDPT, SimulatorEvaluator,
                        default_params)
from repro.core.cache import DPTCache
from repro.core.cluster import fleet_evaluators, make_fleet
from repro.core.search import (coordinate_hillclimb, cost_model_warmstart,
                               goodput_tune, successive_halving,
                               tuned_with_warmstart)
from repro.data.loader import TransferStats
from repro.data.storage import StorageProfile, cifar10_profile


class TableEvaluator:
    """Deterministic synthetic objective with optional overflow cells."""

    def __init__(self, fn, overflow=None):
        self.fn = fn
        self.overflow = overflow or (lambda i, j: False)
        self.calls = []

    def __call__(self, i, j, *, num_batches=16, epoch=0):
        self.calls.append((i, j))
        if self.overflow(i, j):
            raise MemoryOverflow(f"cell ({i},{j})")
        return TransferStats(self.fn(i, j), num_batches, 0)


def test_algorithm1_visits_worker_multiples_of_G():
    ev = TableEvaluator(lambda i, j: abs(i - 8) + 0.1 * abs(j - 3))
    cfg = DPTConfig(num_cpu_cores=12, num_devices=4, max_prefetch=4,
                    num_batches=4)
    res = DPT(ev, cfg).run(measure_default=False)
    workers = {i for i, _ in ev.calls}
    assert workers == {4, 8, 12}          # G, 2G, 3G (i > N stops)
    assert res.nworker == 8 and res.nprefetch == 3


def test_algorithm1_finds_grid_argmin():
    fn = lambda i, j: (i - 6) ** 2 + (j - 2) ** 2 + 1.0
    ev = TableEvaluator(fn)
    cfg = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=6,
                    num_batches=4)
    res = DPT(ev, cfg).run(measure_default=False)
    assert (res.nworker, res.nprefetch) == (6, 2)
    assert res.optimal_time == 1.0


def test_memory_overflow_breaks_inner_loop():
    """Paper Algorithm 1 lines 9-10: overflow -> break to next worker count."""
    ev = TableEvaluator(lambda i, j: 10.0 - i + 0.1 * j,
                        overflow=lambda i, j: j >= 3)
    cfg = DPTConfig(num_cpu_cores=4, num_devices=1, max_prefetch=8,
                    num_batches=4)
    res = DPT(ev, cfg).run(measure_default=False)
    # for every worker count, j stops at 3 (first overflow)
    for i in range(1, 5):
        js = [j for (w, j) in ev.calls if w == i]
        assert js == [1, 2, 3]
    assert res.nprefetch <= 2


def test_default_params_match_pytorch_convention():
    assert default_params(12) == (6, 2)


def test_speedup_and_reduction_sign():
    """An improvement over the defaults is a POSITIVE time reduction."""
    ev = TableEvaluator(lambda i, j: 2.0 if (i, j) != (4, 2) else 1.0)
    cfg = DPTConfig(num_cpu_cores=4, num_devices=4, max_prefetch=2,
                    num_batches=4)
    res = DPT(ev, cfg).run(measure_default=True)
    assert res.speedup_vs_default == 2.0
    assert res.time_reduction_pct == pytest.approx(50.0)


def test_worker_sweep_clamps_final_rung_to_cores():
    """N not divisible by G must not measure more workers than cores."""
    ev = TableEvaluator(lambda i, j: float(i + j))
    cfg = DPTConfig(num_cpu_cores=10, num_devices=4, max_prefetch=2,
                    num_batches=4)
    DPT(ev, cfg).run(measure_default=False)
    workers = {i for i, _ in ev.calls}
    assert workers == {4, 8, 10}          # last rung clamped, not 12


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(2, 16), st.integers(1, 6))
def test_algorithm1_never_beats_exhaustive_property(g, n, p):
    """Property: Algorithm 1's optimum equals the exhaustive grid minimum
    over its own search space."""
    fn = lambda i, j: ((i * 7 + j * 13) % 11) + 1.0
    ev = TableEvaluator(fn)
    cfg = DPTConfig(num_cpu_cores=n, num_devices=g, max_prefetch=p,
                    num_batches=2)
    res = DPT(ev, cfg).run(measure_default=False)
    # mirror Algorithm 1's loop exactly (final rung clamped to N)
    i_vals, i = [], 0
    while i < n:
        i = min(i + g, n)
        i_vals.append(i)
    cells = [(i, j) for i in i_vals for j in range(1, p + 1)]
    assert res.optimal_time == min(fn(i, j) for i, j in cells)


# --------------------------------------------------------------------------
# search strategies agree with the grid on the calibrated simulator
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sim_ev():
    sim = LoaderSimulator(cifar10_profile(), MachineProfile())
    return SimulatorEvaluator(sim, batch_size=32)


CFG = DPTConfig(num_cpu_cores=12, num_devices=1, max_prefetch=8,
                num_batches=64)


def test_successive_halving_matches_grid(sim_ev):
    grid = DPT(sim_ev, CFG).run(measure_default=False)
    sh = successive_halving(sim_ev, config=CFG)
    assert sh.optimal_time <= grid.optimal_time * 1.05


def test_warmstart_hillclimb_matches_grid_with_fewer_calls(sim_ev):
    grid = DPT(sim_ev, CFG).run(measure_default=False)
    ev2 = SimulatorEvaluator(LoaderSimulator(cifar10_profile(),
                                             MachineProfile()), batch_size=32)
    hc = tuned_with_warmstart(ev2, cifar10_profile(), MachineProfile(),
                              batch_size=32, config=CFG)
    assert hc.optimal_time <= grid.optimal_time * 1.02
    assert ev2.calls < len(grid.trials) / 4      # >=4x fewer measurements


def test_goodput_uses_fewer_workers_when_model_is_slow(sim_ev):
    fast = DPT(sim_ev, CFG).run(measure_default=False)
    slow_model = goodput_tune(sim_ev, step_time_s=1.0, num_batches=64,
                              config=CFG)
    assert slow_model.nworker <= fast.nworker


def test_cost_model_prediction_close_to_measured_optimum(sim_ev):
    pred = cost_model_warmstart(cifar10_profile(), MachineProfile(),
                                batch_size=32, config=CFG)
    grid = DPT(sim_ev, CFG).run(measure_default=False)
    assert abs(pred.nworker - grid.nworker) <= 2


# --------------------------------------------------------------------------
# multi-host
# --------------------------------------------------------------------------
def test_multihost_uniform_handles_straggler():
    fleet = make_fleet(MachineProfile(), cifar10_profile(), num_hosts=4,
                       slow_hosts=[1])
    evs = fleet_evaluators(fleet, batch_size=32)
    mh = MultiHostDPT(evs, CFG)
    per_host = mh.run_per_host()
    uniform = mh.run_uniform()
    # fleet time is dictated by the straggler either way
    assert uniform.fleet_time >= per_host.per_host[0].optimal_time
    # uniform must be feasible on every host and not much worse than per-host
    assert uniform.fleet_time <= per_host.fleet_time * 1.05


def test_multihost_per_host_matches_independent_tuning():
    fleet = make_fleet(MachineProfile(), cifar10_profile(), num_hosts=3)
    evs = fleet_evaluators(fleet, batch_size=32)
    res = MultiHostDPT(evs, CFG).run_per_host()
    assert len(set(res.fleet_params)) == 1   # homogeneous hosts agree


# ---- run_uniform edge cases ----------------------------------------------
_EDGE_CFG = DPTConfig(num_cpu_cores=2, num_devices=1, max_prefetch=2,
                      num_batches=2)


def test_multihost_uniform_single_feasible_cell():
    """When only one cell survives on every host, uniform must pick it."""
    only = (1, 1)
    evs = [TableEvaluator(lambda i, j: float(i + j),
                          overflow=lambda i, j: (i, j) != only)
           for _ in range(3)]
    res = MultiHostDPT(evs, _EDGE_CFG).run_uniform()
    assert res.uniform_params == only
    assert res.fleet_params == [only] * 3


def test_multihost_uniform_no_common_feasible_cell_raises():
    """Host A only feasible at i=1, host B only at i=2 -> no uniform cell."""
    ev_a = TableEvaluator(lambda i, j: 1.0, overflow=lambda i, j: i > 1)
    ev_b = TableEvaluator(lambda i, j: 1.0, overflow=lambda i, j: i == 1)
    with pytest.raises(MemoryOverflow):
        MultiHostDPT([ev_a, ev_b], _EDGE_CFG).run_uniform()


def test_multihost_uniform_straggler_picks_max_minimizing_cell():
    """The uniform choice minimizes the fleet MAX, not any host's own
    optimum: host A loves (1,1) but the straggler B is terrible there."""
    ev_a = TableEvaluator(lambda i, j: 1.0 if (i, j) == (1, 1) else 2.0)
    ev_b = TableEvaluator(lambda i, j: 10.0 if (i, j) == (1, 1) else 2.0)
    res = MultiHostDPT([ev_a, ev_b], _EDGE_CFG).run_uniform()
    assert res.uniform_params != (1, 1)
    assert res.fleet_time == 2.0


# --------------------------------------------------------------------------
# result cache (paper §5 reuse claim)
# --------------------------------------------------------------------------
def test_cache_reuses_similar_datasets(tmp_path):
    cache = DPTCache(str(tmp_path / "dpt.json"))
    ev = TableEvaluator(lambda i, j: (i - 6) ** 2 + j)
    cfg = DPTConfig(num_cpu_cores=8, num_devices=1, max_prefetch=3,
                    num_batches=2)
    res = DPT(ev, cfg).run(measure_default=False)
    from repro.utils.fingerprint import dataset_fingerprint
    fp_a = dataset_fingerprint(item_bytes=100_000, decode_cost=1e-8,
                               num_items=50_000)
    fp_similar = dataset_fingerprint(item_bytes=110_000, decode_cost=1e-8,
                                     num_items=52_000)
    fp_different = dataset_fingerprint(item_bytes=4_000_000, decode_cost=1e-8,
                                       num_items=50_000)
    cache.put("machine", fp_a, 32, res)
    assert cache.get("machine", fp_similar, 32) == (res.nworker, res.nprefetch)
    assert cache.get("machine", fp_different, 32) is None
    # persisted
    cache2 = DPTCache(str(tmp_path / "dpt.json"))
    assert cache2.get("machine", fp_a, 32) == (res.nworker, res.nprefetch)
