"""Data pipeline substrate: sampler determinism/partitioning, worker pools,
prefetcher, loader measurement, memory guard."""
import threading
import time

import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.monitor import MemoryBudget, MemoryMonitor, MemoryOverflow
from repro.data import (DataLoader, Dataset, LatencyStorage, LoaderParams,
                        SamplerState, ShardedSampler, synthetic_image_dataset,
                        token_dataset)
from repro.data.dataset import image_transform
from repro.data.prefetcher import DevicePrefetcher
from repro.data.worker_pool import ThreadWorkerPool


# --------------------------------------------------------------------------
# sampler
# --------------------------------------------------------------------------
def test_sampler_epoch_covers_every_item_once():
    s = ShardedSampler(100, 10, shuffle=True, seed=1)
    seen = np.concatenate(list(s.epoch_iter(0)))
    assert sorted(seen) == list(range(100))


def test_sampler_deterministic_given_seed():
    a = list(ShardedSampler(64, 8, seed=3).epoch_iter(0))
    b = list(ShardedSampler(64, 8, seed=3).epoch_iter(0))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = list(ShardedSampler(64, 8, seed=4).epoch_iter(0))
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(1, 5), st.integers(0, 3))
def test_sampler_host_shards_partition_global_batch(hosts, scale, epoch):
    """Property: host shards of each global batch are disjoint and their
    union is exactly the global batch (no duplication/loss across the pod)."""
    gb = hosts * scale * 2
    n = gb * 3 + 5
    shards = [ShardedSampler(n, gb, seed=7, host_index=h, host_count=hosts)
              for h in range(hosts)]
    for b in range(shards[0].batches_per_epoch()):
        parts = [s.local_indices(epoch, b) for s in shards]
        union = np.concatenate(parts)
        assert len(union) == gb
        assert len(set(union.tolist())) == gb


def test_sampler_state_roundtrip_resumes_stream():
    s1 = ShardedSampler(40, 4, seed=0)
    it1 = iter(s1)
    consumed = [next(it1) for _ in range(7)]
    state = SamplerState.from_dict(s1.state.to_dict())

    s2 = ShardedSampler(40, 4, seed=0, state=state)
    it2 = iter(s2)
    np.testing.assert_array_equal(next(it1), next(it2))


# --------------------------------------------------------------------------
# worker pool
# --------------------------------------------------------------------------
def _dataset(n=64, res=8):
    return synthetic_image_dataset(n, res, seed=0)


@pytest.mark.parametrize("workers", [0, 1, 3])
def test_pool_delivers_all_batches(workers):
    ds = _dataset()
    idx = list(ShardedSampler(64, 8, seed=0).epoch_iter(0))
    pool = ThreadWorkerPool(ds, iter(idx), num_workers=workers,
                            prefetch_factor=2)
    batches = list(pool)
    assert len(batches) == 8
    assert all(b["image"].shape == (8, 8, 8, 3) for b in batches)


def test_pool_propagates_worker_errors():
    ds = _dataset()

    def bad_transform(x):
        raise ValueError("boom")

    ds.transform = bad_transform
    idx = list(ShardedSampler(64, 8, seed=0).epoch_iter(0))
    pool = ThreadWorkerPool(ds, iter(idx), num_workers=2, prefetch_factor=2)
    with pytest.raises(ValueError, match="boom"):
        list(pool)


def test_pool_backpressure_bounds_memory():
    """Workers must block once num_workers*prefetch_factor batches queue up."""
    ds = _dataset(n=256)
    idx = list(ShardedSampler(256, 8, seed=0).epoch_iter(0))
    monitor = MemoryMonitor()
    pool = ThreadWorkerPool(ds, iter(idx), num_workers=2, prefetch_factor=2,
                            monitor=monitor)
    time.sleep(0.3)   # let workers fill the queue without consuming
    batch_bytes = 8 * 8 * 8 * 3 * 4 + 8 * 4
    # queue depth 4 + 2 in-flight = at most ~6 outstanding batches
    assert monitor.peak <= 8 * batch_bytes
    list(pool)


def test_memory_overflow_raised_on_budget():
    ds = _dataset(n=64, res=32)
    idx = list(ShardedSampler(64, 16, seed=0).epoch_iter(0))
    budget = MemoryBudget(loader_bytes=1000)   # absurdly small
    pool = ThreadWorkerPool(ds, iter(idx), num_workers=2, prefetch_factor=2,
                            monitor=MemoryMonitor(budget))
    with pytest.raises(MemoryOverflow):
        list(pool)


# --------------------------------------------------------------------------
# device prefetcher
# --------------------------------------------------------------------------
def test_prefetcher_preserves_order_and_content():
    batches = [{"x": np.full((4,), i, np.float32)} for i in range(10)]
    out = list(DevicePrefetcher(iter(batches), depth=3))
    assert len(out) == 10
    for i, b in enumerate(out):
        np.testing.assert_array_equal(np.asarray(b["x"]),
                                      np.full((4,), i, np.float32))


def test_prefetcher_overlaps_production():
    """With depth=2 the consumer should not wait for every item: total time
    ~= max(producer, consumer), not the sum."""
    def slow_producer():
        for i in range(6):
            time.sleep(0.05)
            yield {"x": np.zeros(4, np.float32)}

    t0 = time.perf_counter()
    for _ in DevicePrefetcher(slow_producer(), depth=2):
        time.sleep(0.05)   # consumer work
    elapsed = time.perf_counter() - t0
    assert elapsed < 0.52   # serial would be ~0.6s


# --------------------------------------------------------------------------
# loader end-to-end
# --------------------------------------------------------------------------
def test_loader_epoch_coverage_with_workers():
    ds = token_dataset(96, 16, 100, seed=0)
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=2), shuffle=False,
                    seed=0)
    toks = [b["tokens"] for b in dl.host_batches(epoch=0)]
    assert len(toks) == 12
    assert all(t.shape == (8, 16) for t in toks)


def test_loader_threads_hide_io_latency():
    base = synthetic_image_dataset(128, 16, seed=0)
    lat = LatencyStorage(base.storage, latency_s=2e-3, bandwidth=1e9)
    ds = Dataset(lat, transform=image_transform)
    dl = DataLoader(ds, 16, seed=0)
    t_serial = dl.with_params(LoaderParams(num_workers=0)) \
        .measure_transfer_time(6, to_device=False).seconds
    t_parallel = dl.with_params(LoaderParams(num_workers=4)) \
        .measure_transfer_time(6, to_device=False).seconds
    assert t_parallel < t_serial / 1.5


def test_loader_overflow_returns_inf_stats():
    ds = _dataset(n=64, res=32)
    dl = DataLoader(ds, 16, params=LoaderParams(num_workers=2),
                    memory_budget=MemoryBudget(loader_bytes=1000), seed=0)
    stats = dl.measure_transfer_time(4)
    assert stats.overflowed


def test_loader_state_dict_roundtrip():
    ds = token_dataset(64, 8, 50)
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=1,
                                               prefetch_factor=3), seed=0)
    it = iter(dl)
    next(it)
    sd = dl.state_dict()
    dl2 = DataLoader(ds, 8, seed=0)
    dl2.load_state_dict(sd)
    assert dl2.params.prefetch_factor == 3
    assert dl2.sampler.state.epoch == dl.sampler.state.epoch


def test_page_cache_effect_in_latency_storage():
    base = synthetic_image_dataset(32, 16, seed=0)
    lat = LatencyStorage(base.storage, latency_s=3e-3, bandwidth=1e9,
                         cache_bytes=10**9)
    ds = Dataset(lat, transform=image_transform)
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=2), seed=0)
    cold = dl.measure_transfer_time(4, epoch=0, to_device=False).seconds
    warm = dl.measure_transfer_time(4, epoch=1, to_device=False).seconds
    assert warm < cold / 2
    assert lat.cache_hits > 0
