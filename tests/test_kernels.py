"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis property tests on the oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from repro.kernels.rmsnorm import rmsnorm_residual
from repro.kernels.ssd_scan import ssd_scan


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) \
        .astype(dtype)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,T,H,K,D,causal,window",
    [
        (1, 32, 32, 4, 4, 16, True, 0),      # MHA causal
        (2, 64, 64, 4, 2, 32, True, 0),      # GQA causal
        (2, 48, 48, 6, 2, 16, False, 0),     # non-causal (encoder)
        (1, 64, 64, 4, 1, 16, True, 20),     # sliding window, MQA
        (2, 40, 40, 4, 4, 24, True, 0),      # non-pow2 seq + head_dim pad
        (1, 128, 128, 8, 8, 64, True, 48),   # bigger window
    ])
def test_flash_matches_oracle(B, S, T, H, K, D, causal, window, dtype):
    q = _rand(0, (B, S, H, D), dtype)
    k = _rand(1, (B, T, K, D), dtype)
    v = _rand(2, (B, T, K, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_k=16, interpret=True)
    expect = ref.mha(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_block_shape_invariance():
    q = _rand(0, (2, 64, 4, 32), jnp.float32)
    k = _rand(1, (2, 64, 2, 32), jnp.float32)
    v = _rand(2, (2, 64, 2, 32), jnp.float32)
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(8, 8), (16, 32), (64, 64), (32, 8)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


def test_chunked_oracle_matches_full():
    q = _rand(0, (2, 100, 4, 16), jnp.float32)
    k = _rand(1, (2, 100, 2, 16), jnp.float32)
    v = _rand(2, (2, 100, 2, 16), jnp.float32)
    for window, sink in [(0, 0), (24, 0), (24, 4)]:
        full = ref.mha(q, k, v, causal=True, window=window, num_sink=sink)
        chunk = ref.mha_chunked(q, k, v, causal=True, window=window,
                                num_sink=sink, block_q=32)
        np.testing.assert_allclose(np.asarray(full), np.asarray(chunk),
                                   atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 24), st.integers(1, 4),
       st.integers(0, 1), st.booleans())
def test_attention_causality_property(b, s, k, g_extra, causal):
    """Property: output at position i never depends on tokens > i (causal)."""
    h = k * (1 + g_extra)
    q = _rand(3, (b, s, h, 8), jnp.float32)
    kk = _rand(4, (b, s, k, 8), jnp.float32)
    v = _rand(5, (b, s, k, 8), jnp.float32)
    out = ref.mha(q, kk, v, causal=causal)
    if causal and s > 1:
        # perturb the last token; all earlier outputs must be unchanged
        kk2 = kk.at[:, -1].add(10.0)
        v2 = v.at[:, -1].add(10.0)
        out2 = ref.mha(q, kk2, v2, causal=True)
        np.testing.assert_allclose(np.asarray(out[:, :-1]),
                                   np.asarray(out2[:, :-1]),
                                   atol=1e-5, rtol=1e-5)
    # rows are convex combos of V: bounded by V extrema
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 128), (3, 7, 100), (1, 1, 1, 256),
                                   (5, 333)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_oracle(shape, dtype):
    x = _rand(0, shape, dtype)
    scale = _rand(1, shape[-1:], jnp.float32)
    out = rmsnorm_kernel(x, scale, interpret=True)
    expect = ref.rmsnorm(x, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_rmsnorm_residual_fusion():
    x = _rand(0, (4, 37, 96), jnp.float32)
    res = _rand(1, (4, 37, 96), jnp.float32)
    scale = _rand(2, (96,), jnp.float32)
    normed, new_res = rmsnorm_residual(x, res, scale, interpret=True)
    np.testing.assert_allclose(np.asarray(new_res), np.asarray(x + res),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(normed),
                               np.asarray(ref.rmsnorm(x + res, scale)),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(1, 300))
def test_rmsnorm_scale_property(rows, d):
    """rmsnorm(a*x) == rmsnorm(x) for positive scalar a (scale-invariant —
    up to the eps regularizer, so keep |x| well above sqrt(eps))."""
    x = jnp.abs(_rand(0, (rows, d), jnp.float32)) + 0.5
    s = jnp.ones((d,))
    a = 3.7
    np.testing.assert_allclose(np.asarray(ref.rmsnorm(a * x, s)),
                               np.asarray(ref.rmsnorm(x, s)),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# SSD scan
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (1, 32, 2, 8, 1, 4, 8),
        (2, 64, 4, 16, 2, 8, 16),
        (2, 64, 4, 16, 4, 8, 32),     # groups == heads
        (1, 96, 6, 8, 2, 16, 24),     # non-pow2 chunk
    ])
def test_ssd_kernel_matches_naive(b, s, h, p, g, n, chunk):
    x = _rand(0, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(_rand(1, (b, s, h), jnp.float32))
    A = -jnp.exp(_rand(2, (h,), jnp.float32) * 0.5)
    B = _rand(3, (b, s, g, n), jnp.float32)
    C = _rand(4, (b, s, g, n), jnp.float32)
    expect, _ = ref.ssd_naive(x, dt, A, B, C)
    kern = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    chunked, _ = ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(expect),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(expect),
                               atol=1e-4, rtol=1e-3)


def test_ssd_decode_step_matches_scan():
    b, s, h, p, g, n = 2, 16, 2, 8, 1, 4
    x = _rand(0, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(_rand(1, (b, s, h), jnp.float32))
    A = -jnp.exp(_rand(2, (h,), jnp.float32) * 0.5)
    B = _rand(3, (b, s, g, n), jnp.float32)
    C = _rand(4, (b, s, g, n), jnp.float32)
    y_full, final_state = ref.ssd_naive(x, dt, A, B, C)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ref.ssd_step(state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(final_state),
                               atol=1e-4, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 48), st.integers(1, 3))
def test_ssd_chunk_invariance_property(s, b):
    """Property: chunked SSD is chunk-size invariant (same math)."""
    h, p, g, n = 2, 4, 1, 4
    s = (s // 8) * 8
    x = _rand(0, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(_rand(1, (b, s, h), jnp.float32))
    A = -jnp.exp(_rand(2, (h,), jnp.float32) * 0.5)
    B = _rand(3, (b, s, g, n), jnp.float32)
    C = _rand(4, (b, s, g, n), jnp.float32)
    y8, st8 = ref.ssd_chunked(x, dt, A, B, C, chunk=8)
    y4, st4 = ref.ssd_chunked(x, dt, A, B, C, chunk=4)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y4),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st8), np.asarray(st4),
                               atol=1e-4, rtol=1e-3)
