"""CI per-test duration budget gate.

The tier-1 run writes per-test call durations to the JSON named by
``REPRO_DURATIONS_JSON`` (see conftest.py); this script fails when any
single test exceeds the budget — so a slow-test regression in the data
pipeline shows up red in the PR instead of silently inflating CI time.

Usage: python tests/check_durations.py durations.json --budget 90
"""
import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="durations JSON written by the test run")
    ap.add_argument("--budget", type=float, default=90.0,
                    help="max seconds any single test may take")
    ap.add_argument("--top", type=int, default=10,
                    help="how many slowest tests to print")
    args = ap.parse_args(argv)

    with open(args.path) as f:
        durations = json.load(f)
    ranked = sorted(durations.items(), key=lambda kv: -kv[1])
    print(f"{len(durations)} tests timed; slowest {args.top}:")
    for nodeid, secs in ranked[:args.top]:
        print(f"  {secs:8.2f}s  {nodeid}")
    over = [(n, s) for n, s in ranked if s > args.budget]
    if over:
        print(f"\nFAIL: {len(over)} test(s) over the {args.budget:.0f}s "
              "per-test budget:")
        for nodeid, secs in over:
            print(f"  {secs:8.2f}s  {nodeid}")
        return 1
    print(f"\nOK: all tests within the {args.budget:.0f}s per-test budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
