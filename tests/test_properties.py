"""Property-based suite for the whole data pipeline (ISSUE 5).

Replaces the hand-enumerated coverage/permutation case lists that used to
live in test_locality.py / test_fleet.py with randomized configurations:
for arbitrary (dataset size, shard counts, locality_chunk, global batch,
reshard point, checkpoint point, layout) tuples the pipeline must hold

* **permutation-ness** — every epoch order is exactly a permutation;
* **exact once-per-epoch coverage** — including across a mid-epoch
  reshard (old-shard slices before the barrier + new-shard slices after
  union to the epoch, for any chunk size and either host layout);
* **checkpoint determinism** — a sampler restored mid-epoch with the new
  topology reproduces the live continuation exactly;
* **byte-identical multisets** — a chunked epoch delivers the same
  sample bytes as the random epoch, through the real loader machinery;

plus two seeded fault-injection matrices for the fleet control plane:
randomized join/leave/degrade/correlated-death timelines must lose and
duplicate zero batches, with exactly one reshard per correlated-death
group; and the same guarantees over a faulty transport (drop, delay,
duplicate, partition windows) with a coordinator crash + standby
failover mid-run under fencing (ISSUE 7, DESIGN.md §8).

Runs under real hypothesis when installed (CI) and under the shim's
deterministic fallback engine otherwise — either way the suite executes
well over 100 randomized pipeline configurations.
"""
import time

import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from conftest import flat_indices, make_index_dataset

from repro.core.cluster import FleetEvent, FleetSchedule
from repro.data import DataLoader, LoaderParams
from repro.data.sampler import SamplerState, ShardedSampler

# chunk candidates deliberately include 0/1 (random), odd sizes, sizes
# around the batch, and sizes past the dataset
_CHUNKS = (0, 1, 3, 8, 16, 64, 200, 777)


def _shards(n, gb, hosts, *, chunk, layout, seed):
    return [ShardedSampler(n, gb, seed=seed, host_index=h, host_count=hosts,
                           locality_chunk=chunk, layout=layout)
            for h in range(hosts)]


# --------------------------------------------------------------------------
# the core pipeline property: permutation + exact coverage across a
# mid-epoch reshard + checkpoint determinism, randomized
# --------------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4),
       st.sampled_from(_CHUNKS), st.integers(2, 6), st.integers(1, 3),
       st.sampled_from(["host_major", "strided"]),
       st.integers(0, 99), st.integers(0, 10**6))
def test_pipeline_coverage_reshard_checkpoint_property(
        old_hosts, new_hosts, chunk, bpe, gb_scale, layout, cut, seed):
    """For ANY randomized pipeline config: exact once-per-epoch coverage
    across a mid-epoch reshard, permutation-ness, and checkpoint
    round-trip determinism."""
    gb = 12 * gb_scale                  # divisible by every host count <= 4
    n = gb * bpe
    barrier = cut % (bpe + 1)           # reshard point, 0..bpe inclusive
    ckpt = (cut * 7 + seed) % bpe       # checkpoint point within the epoch

    # permutation-ness (both epochs; chunked or not, either layout)
    probe = ShardedSampler(n, gb, seed=seed, locality_chunk=chunk,
                           layout=layout)
    for epoch in (0, 1):
        assert sorted(probe._epoch_perm(epoch).tolist()) == list(range(n))

    # exact coverage across the reshard barrier
    old = _shards(n, gb, old_hosts, chunk=chunk, layout=layout, seed=seed)
    seen = []
    for b in range(barrier):
        for s in old:
            seen.extend(s.local_indices(0, b).tolist())
    for h, s in enumerate(old[:min(old_hosts, new_hosts)]):
        s.reshard(new_hosts, h)
    survivors = old[:min(old_hosts, new_hosts)]
    joined = _shards(n, gb, new_hosts, chunk=chunk, layout=layout,
                     seed=seed)[len(survivors):]
    for b in range(barrier, bpe):
        for s in survivors + joined:
            seen.extend(s.local_indices(0, b).tolist())
    assert sorted(seen) == list(range(n))

    # checkpoint round-trip: a fresh sampler restored at ``ckpt`` with the
    # NEW topology continues exactly like the live one
    live = ShardedSampler(n, gb, seed=seed, host_index=0,
                          host_count=old_hosts, locality_chunk=chunk,
                          layout=layout)
    it = iter(live)
    for _ in range(ckpt):
        next(it)
    saved = live.state.to_dict()
    live.reshard(new_hosts, 0)
    expect = [next(it).tolist() for _ in range(3)]
    restored = ShardedSampler(n, gb, seed=seed, host_index=0,
                              host_count=new_hosts, locality_chunk=chunk,
                              layout=layout,
                              state=SamplerState.from_dict(saved))
    again = [next(iter(restored)).tolist() for _ in range(3)]
    assert expect == again


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.sampled_from(_CHUNKS), st.integers(0, 3),
       st.integers(0, 10**6))
def test_host_layouts_partition_identically_property(hosts, chunk, epoch,
                                                     seed):
    """Host-major and strided layouts partition every global batch into
    the SAME set — layout changes locality, never coverage."""
    gb, n = 24, 24 * 4
    major = _shards(n, gb, hosts, chunk=chunk, layout="host_major",
                    seed=seed)
    strided = _shards(n, gb, hosts, chunk=chunk, layout="strided",
                      seed=seed)
    for b in range(n // gb):
        a = np.concatenate([s.local_indices(epoch, b) for s in major])
        d = np.concatenate([s.local_indices(epoch, b) for s in strided])
        assert sorted(a.tolist()) == sorted(d.tolist())


@pytest.mark.parametrize("hosts", [2, 4])
@pytest.mark.parametrize("chunk", [8, 16])
def test_host_major_preserves_per_host_run_length(hosts, chunk):
    """The PR-4 fleet degradation, fixed: under host striding per-host
    coalesced runs collapse (the within-chunk shuffle makes every H-th
    position a near-random value, runs -> ~1), while the host-major
    layout keeps whole chunks on one host — per-host runs stay ~C
    whenever the chunk fits the local batch (C <= B/H, which the
    per-host-measuring DPT grid selects for naturally)."""
    from repro.data.storage import coalesce_runs
    gb, n = 64, 64 * 8                       # C <= lb at every H here

    def mean_run(layout):
        shards = _shards(n, gb, hosts, chunk=chunk, layout=layout, seed=1)
        runs = [len(coalesce_runs(s.local_indices(0, b)))
                for s in shards for b in range(n // gb)]
        lb = gb // hosts
        return lb * len(runs) / sum(runs)    # mean items per request

    assert mean_run("host_major") >= 0.5 * chunk
    assert mean_run("strided") <= 0.5 * mean_run("host_major")


# --------------------------------------------------------------------------
# byte-identical multisets through the real loader machinery
# --------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.sampled_from((1, 3, 8, 16, 200)), st.integers(1, 2),
       st.integers(0, 10**6))
def test_chunked_epoch_byte_identical_multiset_property(chunk, hosts, seed):
    """A chunked epoch delivers exactly the random epoch's sample bytes
    (chunking reorders, never re-samples) — through the real worker-pool
    delivery path, at any shard count."""
    n, gb = 96, 24

    def epoch_bytes(locality_chunk):
        out = []
        for h in range(hosts):
            dl = DataLoader(make_index_dataset(n), gb,
                            params=LoaderParams(
                                num_workers=1,
                                locality_chunk=locality_chunk),
                            shuffle=True, seed=seed,
                            host_index=h, host_count=hosts)
            for batch in dl.host_batches(epoch=0, num_batches=n // gb):
                out.extend(r.tobytes() for r in np.asarray(batch["x"]))
        return out

    a = sorted(epoch_bytes(0))
    b = sorted(epoch_bytes(chunk))
    assert a == b


# --------------------------------------------------------------------------
# the cache dimension (DESIGN.md §7): the cross-epoch tier and the
# cache-aware interleaved order must never touch coverage or bytes
# --------------------------------------------------------------------------
# off / a few hot chunks / everything fits
_BUDGETS = (0, 16 * 1024, 1 << 40)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.sampled_from((4, 16, 64)),
       st.integers(0, 12), st.integers(2, 5),
       st.sampled_from(["host_major", "strided"]),
       st.integers(0, 99), st.integers(0, 10**6))
def test_cache_plan_coverage_reshard_checkpoint_property(
        old_hosts, new_hosts, chunk, hot_k, bpe, layout, cut, seed):
    """The cache-aware interleaved order holds the same invariants as the
    plain chunked order: permutation-ness, exact once-per-epoch coverage
    across a mid-epoch reshard, and checkpoint determinism — for ANY
    (chunk, hot_k) plan, including hot_k past the chunk count."""
    gb = 12
    n = gb * bpe
    barrier = cut % (bpe + 1)

    def shards(hosts):
        out = _shards(n, gb, hosts, chunk=chunk, layout=layout, seed=seed)
        for s in out:
            s.force_cache_plan(hot_k)
        return out

    probe = shards(1)[0]
    for epoch in (0, 1):
        assert sorted(probe._epoch_perm(epoch).tolist()) == list(range(n))
    # a trial override stays plan-blind: same order as a plan-free sampler
    plain = _shards(n, gb, 1, chunk=chunk, layout=layout, seed=seed)[0]
    assert probe._epoch_perm(0, chunk).tolist() \
        == plain._epoch_perm(0, chunk).tolist()

    old = shards(old_hosts)
    seen = []
    for b in range(barrier):
        for s in old:
            seen.extend(s.local_indices(0, b).tolist())
    for h, s in enumerate(old[:min(old_hosts, new_hosts)]):
        s.reshard(new_hosts, h)
    survivors = old[:min(old_hosts, new_hosts)]
    joined = shards(new_hosts)[len(survivors):]
    for b in range(barrier, bpe):
        for s in survivors + joined:
            seen.extend(s.local_indices(0, b).tolist())
    assert sorted(seen) == list(range(n))

    # checkpoint round-trip with the plan in effect
    live = shards(old_hosts)[0]
    it = iter(live)
    for _ in range((cut * 7 + seed) % bpe):
        next(it)
    saved = live.state.to_dict()
    plan = live.cache_state()
    live.reshard(new_hosts, 0)
    expect = [next(it).tolist() for _ in range(3)]
    restored = ShardedSampler(n, gb, seed=seed, host_index=0,
                              host_count=new_hosts, locality_chunk=chunk,
                              layout=layout,
                              state=SamplerState.from_dict(saved))
    restored.load_cache_plan(plan)
    again = [next(iter(restored)).tolist() for _ in range(3)]
    assert expect == again


@settings(max_examples=8, deadline=None)
@given(st.sampled_from((0, 8, 16)), st.sampled_from(_BUDGETS[1:]),
       st.integers(0, 10**6))
def test_cached_stream_byte_identical_multiset_property(chunk, budget, seed):
    """A cache-tier stream (any budget) delivers exactly the cache-off
    stream's sample bytes in EVERY epoch — cold (admitting) and warm
    (serving hits).  The interleave reorders an epoch; it never
    re-samples, drops, or serves stale items."""
    n, gb = 96, 24
    bpe = n // gb

    def stream_bytes(cache_budget):
        dl = DataLoader(make_index_dataset(n), gb,
                        params=LoaderParams(
                            num_workers=1, locality_chunk=chunk,
                            cache_budget_bytes=cache_budget),
                        shuffle=True, seed=seed)
        out = {0: [], 1: []}
        s = dl.stream(to_device=False)
        try:
            for epoch in (0, 1):
                for _ in range(bpe):
                    out[epoch].extend(r.tobytes()
                                      for r in np.asarray(next(s)["x"]))
        finally:
            s.close()
        return out

    base = stream_bytes(0)
    cached = stream_bytes(budget)
    for epoch in (0, 1):
        assert sorted(base[epoch]) == sorted(cached[epoch])


def test_cached_loader_checkpoint_roundtrip_warm():
    """Checkpoint + restore with a WARM cache tier: the restored loader
    reproduces the live continuation exactly (the cache plan rides the
    state dict; the restored tier starts cold and only changes timing,
    never order or bytes)."""
    n, gb = 96, 24
    bpe = n // gb

    def make():
        return DataLoader(make_index_dataset(n), gb,
                          params=LoaderParams(
                              num_workers=1, locality_chunk=8,
                              cache_budget_bytes=1 << 40),
                          shuffle=True, seed=3)

    live = make()
    s = live.stream(to_device=False)
    try:
        for _ in range(bpe + 1):         # into epoch 1: the tier is warm
            next(s)
        assert live.cache_tier is not None and len(live.cache_tier) > 0
        saved = live.state_dict()
        # the producer runs ahead of the consumer (prefetch): checkpoint
        # the CONSUMED position, like the trainer does
        saved["sampler"] = SamplerState.from_absolute(
            s.position, bpe).to_dict()
        expect = [sorted(np.asarray(next(s)["x"]).reshape(-1).tolist())
                  for _ in range(3)]
    finally:
        s.close()

    restored = make()
    restored.load_state_dict(saved)
    s2 = restored.stream(to_device=False)
    try:
        again = [sorted(np.asarray(next(s2)["x"]).reshape(-1).tolist())
                 for _ in range(3)]
    finally:
        s2.close()
    assert expect == again


# --------------------------------------------------------------------------
# seeded fault-injection matrix: the fleet under randomized timelines
# --------------------------------------------------------------------------
def _build_timeline(rng, *, max_step, timeout_rounds):
    """Random join/leave/degrade events, spaced > heartbeat timeout so
    correlated-death groups resolve to distinct detection windows.  Every
    timeline contains at least one death group (the matrix must exercise
    the reshard path on every seed)."""
    events, step = [], 2
    hosts_alive, next_host = 3, 3
    groups = []                          # correlated-death groups emitted
    while step < max_step:
        kind = rng.choice(["death", "join", "degrade", "none"],
                          p=[0.45, 0.25, 0.2, 0.1])
        if not groups and step + timeout_rounds + 3 >= max_step:
            kind = "death"               # last slot: force the guarantee
        if kind == "death" and hosts_alive >= 2:
            size = int(rng.integers(1, min(2, hosts_alive - 1) + 1))
            events.append(("death", step, size))
            groups.append(size)
            hosts_alive -= size
        elif kind == "join" and hosts_alive < 4:
            events.append(("join", step, next_host))
            next_host += 1
            hosts_alive += 1
        elif kind == "degrade":
            events.append(("degrade", step, None))
        step += timeout_rounds + 3
    return events, groups


@pytest.mark.parametrize("seed", range(8))
def test_fleet_fault_injection_matrix(seed):
    """Randomized fleet timelines (correlated deaths, joins, degrades at
    seeded random steps): zero lost/duplicated batches over the epoch and
    exactly one reshard emitted per correlated-death group."""
    from repro.data import DataLoader, LoaderParams
    from repro.tuning import FleetConfig, FleetCoordinator, HostAgent
    from conftest import make_table_evaluator

    rng = np.random.default_rng(seed)
    gb, bpe = 12, 48
    n = gb * bpe
    timeout, rounds = 4.0, 40
    events, groups = _build_timeline(rng, max_step=rounds - 12,
                                     timeout_rounds=int(timeout))
    sched = FleetSchedule()
    for kind, step, arg in events:
        if kind == "death":
            sched.add(FleetEvent(step=step, kind="leave", host=f"g{arg}"))
        elif kind == "join":
            sched.add(FleetEvent(step=step, kind="join", host=f"host{arg}"))
        else:
            sched.add(FleetEvent(step=step, kind="degrade", host="host0",
                                 io_scale=4.0))

    clock = [0.0]
    coord = FleetCoordinator(
        config=FleetConfig(heartbeat_timeout_s=timeout, warmup_steps=2,
                           cooldown_steps=8, num_cpu_cores=4, num_devices=1,
                           max_prefetch=2, retune_budget_batches=2),
        clock=lambda: clock[0])

    def spawn(h, host_count):
        dl = DataLoader(make_index_dataset(n), gb, shuffle=True, seed=7,
                        params=LoaderParams(num_workers=2,
                                            prefetch_factor=2),
                        host_index=h, host_count=host_count)
        return HostAgent(f"host{h}", dl,
                         evaluator=make_table_evaluator(
                             lambda i, j: 4.0 / i + 0.1 * j))

    agents = {f"host{h}": coord.register(spawn(h, 3)) for h in range(3)}
    streams = {name: a.loader.stream(to_device=False)
               for name, a in agents.items()}
    alive = set(agents)
    degraded = set()
    delivered = []
    death_steps = []

    try:
        for step in range(rounds):
            for ev in sched.at(step):
                if ev.kind == "leave":       # a correlated-death group
                    size = int(ev.host[1:])
                    victims = sorted(alive)[:size]
                    for v in victims:
                        alive.discard(v)
                    death_steps.append(step)
                elif ev.kind == "join":
                    h = int(ev.host[4:])
                    agent = spawn(h, 1)      # coord.join reshards it in
                    coord.join(agent)
                    agents[ev.host] = agent
                    streams[ev.host] = agent.loader.stream(to_device=False)
                    alive.add(ev.host)
                else:
                    degraded.add(ev.host)
            clock[0] += 1.0
            for name in sorted(alive):
                delivered.append(next(streams[name]))
                scale = 4.0 if name in degraded else 1.0
                agents[name].observe(data_s=0.001, step_s=0.05 * scale)
            coord.poll()

        for name in sorted(alive):
            s = streams[name]
            while s.position < bpe:
                delivered.append(next(s))
    finally:
        for s in streams.values():
            s.close()

    # zero lost, zero duplicated — the epoch's exact multiset
    assert flat_indices(delivered) == list(range(n))
    # exactly ONE reshard per correlated-death group
    death_reshards = [e for e in coord.events
                      if e["kind"] == "reshard" and e["reason"] == "dead"]
    assert len(death_reshards) == len(groups), coord.events
    for event, size in zip(death_reshards, groups):
        assert len(event["lost"]) == size
    # joins each emitted their own reshard
    joins = [e for e in coord.events if e["kind"] == "join"]
    assert len(joins) == sum(1 for k, _, _ in events if k == "join")


# --------------------------------------------------------------------------
# network-fault matrix: the same guarantees over a faulty wire, with a
# coordinator crash + standby failover mid-reshard (ISSUE 7, DESIGN.md §8)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(6))
def test_fleet_network_fault_matrix(seed, wire_fleet):
    """Seeded network-fault timelines over the message transport: random
    drop/delay/duplicate/reply-drop rates, partition windows shorter than
    the heartbeat timeout on the surviving hosts, one coordinator crash
    (standby promotes via the lease) and one host death after failover.
    The epoch must still be the exact multiset — zero lost, zero
    duplicated batches — with exactly one reshard applied for the death
    (idempotent replay under fencing, never a double application) and
    every post-failover command carrying the promoted leader's fence.

    Partition windows are capped below the heartbeat timeout on purpose:
    a longer partition is indistinguishable from death, so the fleet
    legitimately evicts and reshards around the host (covered by
    test_transport.py's eviction test).  The dying host's final report is
    flushed before it is killed — a host that consumed batches but never
    reported them trades a duplicate for a loss by design (two generals;
    see DESIGN.md §8)."""
    from repro.tuning import FaultSpec

    rng = np.random.default_rng(100 + seed)
    faults = FaultSpec(drop=float(rng.uniform(0, 0.05)),
                       delay=float(rng.uniform(0, 0.04)),
                       duplicate=float(rng.uniform(0, 0.05)),
                       reply_drop=float(rng.uniform(0, 0.05)),
                       seed=seed)
    fleet = wire_fleet(faults=faults)

    crash_at = int(rng.integers(6, 13))
    death_at = crash_at + int(rng.integers(9, 13))
    # two partition windows on the SURVIVORS (host0/host1), each shorter
    # than the heartbeat timeout (6.0): tolerated, never an eviction
    cuts = {}
    for host, lo, hi in ((0, 3, crash_at),
                        (1, crash_at + 1, death_at + 2)):
        start = int(rng.integers(lo, hi))
        dur = int(rng.integers(1, 4))
        cuts.setdefault(start, []).append((host, "cut"))
        cuts.setdefault(start + dur, []).append((host, "heal"))

    def apply_cuts(step):
        for host, action in cuts.get(step, ()):
            if action == "cut":
                fleet.transport.partition(f"host{host}", "coord")
            else:
                fleet.transport.heal(f"host{host}", "coord")

    step = 0
    while step < death_at:
        apply_cuts(step)
        if step == crash_at:
            fleet.server.crash()
        fleet.rounds(1)
        step += 1

    assert fleet.replica.promoted, "standby never promoted after crash"
    new_fence = fleet.server.fence
    assert new_fence > 1, "promotion must mint a fresh fencing epoch"

    # land host2's final report, then kill it: the coordinator's makeup
    # math works from the last *reported* consumed position
    for _ in range(30):
        fleet.clock[0] += 0.01
        fleet.transport.pump()
        if fleet.agents[2].link.send_report(fleet.agents[2].report_wire()):
            break
    else:
        pytest.fail("host2 could not land its final report")

    def death_reshards():
        return [e for e in fleet.coord.events if e["kind"] == "reshard"
                and str(e["reason"]).startswith("dead")]

    for _ in range(25):
        if death_reshards():
            break
        apply_cuts(step)
        fleet.rounds(1, alive=[0, 1])
        step += 1
    # settle: heal any still-open window, replay anything pending
    for s in range(step, max(cuts, default=0) + 1):
        apply_cuts(s)
    fleet.rounds(3, alive=[0, 1])
    fleet.drain([0, 1])
    fleet.close()

    # zero lost, zero duplicated over the whole faulty timeline
    assert flat_indices(fleet.delivered) == list(range(fleet.n))
    # the death was resharded exactly once (a fenced replay appends
    # "+replay" to the same event; an interrupted attempt appends none)
    assert len(death_reshards()) == 1, fleet.coord.events
    # survivors follow the promoted leader: every post-failover command
    # carried the new fence, and the old leader can no longer act
    for h in (0, 1):
        assert fleet.agents[h].link.fence == new_fence
    assert fleet.server.fence == new_fence and not fleet.server.deposed


# --------------------------------------------------------------------------
# the dual-lane dimension (DESIGN.md §9): slow-sample isolation must never
# touch order, coverage, or the hot-swap / reshard guarantees
# --------------------------------------------------------------------------
def _tail_transform(a):
    """Planted stragglers: every 16th index sleeps — a deterministic
    heavy-tailed per-item cost with no RNG state to share."""
    if int(a[0]) % 16 == 0:
        time.sleep(2e-3)
    return {"x": a}


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(0, 3), st.integers(0, 10**6))
def test_dual_lane_ordered_coverage_hot_swap_property(lane_w, look, seed):
    """With stragglers planted and the slow lane active at ANY (width,
    lookahead): the epoch arrives in exact sampler order and exact
    coverage, and a mid-epoch hot swap that changes the lane width loses
    and duplicates nothing — the early-started slow batches are all
    delivered or all re-pulled, never dropped."""
    n, gb = 96, 8
    bpe = n // gb
    params = LoaderParams(num_workers=2, prefetch_factor=2, ordered=True,
                          slow_lane_workers=lane_w,
                          slow_lane_lookahead=4 * look)
    dl = DataLoader(make_index_dataset(n, transform=_tail_transform), gb,
                    params=params, shuffle=True, seed=seed)
    # epoch 0 warms the cost tracker: order + coverage with a cold lane
    batches = list(dl.host_batches(epoch=0, num_batches=bpe))
    assert flat_indices(batches) == list(range(n))
    want = [dl.sampler.local_indices(0, b).tolist() for b in range(bpe)]
    assert [np.asarray(b["x"])[:, 0].tolist() for b in batches] == want

    # epoch 0 again via the live stream, swapping the lane mid-epoch —
    # now the warm tracker actively routes to the slow lane
    stream = dl.stream(to_device=False)
    seen = [np.asarray(next(stream)["x"])[:, 0].copy() for _ in range(3)]
    dl.apply_params(params.replace(num_workers=3,
                                   slow_lane_workers=(lane_w % 3) + 1))
    while stream.position < bpe:
        seen.append(np.asarray(next(stream)["x"])[:, 0].copy())
    stream.close()
    flat = np.concatenate(seen).tolist()
    assert sorted(flat) == list(range(n))
    assert flat == [i for b in want for i in b], \
        "hot swap broke ordered delivery"


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.integers(1, 4), st.integers(0, 10**6))
def test_dual_lane_survives_reshard_property(lane_w, barrier, seed):
    """A mid-epoch reshard with the slow lane live: one host dies, the
    survivor takes over the whole stream at the barrier — the epoch union
    is still the exact multiset (the slow lane's run-ahead batches are
    rewound with everything else, zero lost, zero duplicated)."""
    n, gb = 96, 12
    bpe = n // gb
    params = LoaderParams(num_workers=2, prefetch_factor=2, ordered=True,
                          slow_lane_workers=lane_w, slow_lane_lookahead=8)

    def mk(h, hc):
        return DataLoader(make_index_dataset(n, transform=_tail_transform),
                          gb, params=params, shuffle=True, seed=seed,
                          host_index=h, host_count=hc)

    dls = [mk(0, 2), mk(1, 2)]
    streams = [dl.stream(to_device=False) for dl in dls]
    delivered = []
    try:
        for _ in range(barrier):
            for s in streams:
                delivered.append(next(s))
        streams[1].close()               # host1 dies at the barrier
        dls[0].reshard(1, 0, at_batch=barrier)
        while streams[0].position < bpe:
            delivered.append(next(streams[0]))
    finally:
        for s in streams:
            s.close()
    assert flat_indices(delivered) == list(range(n))


# --------------------------------------------------------------------------
# fault dimension (DESIGN.md §10): randomized corrupt sets + transient
# rates must never cost a non-quarantined sample
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 4),
       st.sampled_from(["skip", "substitute"]),
       st.sampled_from((0.0, 0.1)), st.integers(0, 10**6))
def test_fault_quarantine_coverage_property(bpe, gb_scale, nbad, mode,
                                            transient, seed):
    """For ANY randomized fault config (corrupt set, transient rate,
    bad-sample policy, epoch shape): under ``skip`` the delivered multiset
    is exactly the epoch minus the quarantined ids; under ``substitute``
    batch sizes are preserved and no corrupt id is ever delivered.  The
    quarantine ends up naming exactly the corrupt set — transient faults
    are retried away, never quarantined."""
    from repro.data import Dataset, FaultyStorage, StorageFaultSpec
    from repro.data.storage import ArrayStorage

    gb = 8 * gb_scale
    n = gb * bpe
    rng = np.random.default_rng(seed)
    bad = tuple(sorted(rng.choice(n, size=nbad, replace=False).tolist()))
    ds = Dataset(
        FaultyStorage(ArrayStorage(
            [np.full((4,), i, np.int32) for i in range(n)]),
            StorageFaultSpec(corrupt_items=bad, transient_rate=transient,
                             seed=seed % 997)),
        transform=lambda a: {"x": a})
    dl = DataLoader(ds, gb, params=LoaderParams(
        num_workers=2, on_bad_sample=mode, retry_attempts=8,
        retry_backoff_s=1e-4, retry_deadline_s=5.0),
        shuffle=True, seed=seed)
    got = list(dl.host_batches(epoch=0))
    flat = [int(i) for b in got for i in np.asarray(b["x"])[:, 0]]
    assert sorted(dl.quarantine.ids().tolist()) == list(bad)
    if mode == "skip":
        assert sorted(flat) == [i for i in range(n) if i not in bad]
    else:
        assert len(flat) == n            # batch sizes preserved
        assert not set(bad) & set(flat)  # corrupt ids replaced
        assert set(flat) <= set(range(n))


# --------------------------------------------------------------------------
# elastic geometry (DESIGN.md §11): the epoch-latched global-batch schedule
# + the two divisibility regressions it fixes (PR 10)
# --------------------------------------------------------------------------
def test_plan_remesh_snaps_nondivisible_global_batch_regression():
    """Regression: a 4->3 shrink of global batch 14 rounds to a per-plan
    batch (10 or 11) that 3 hosts cannot shard uniformly.  plan_remesh
    must snap to the nearest positive multiple of the survivor count and
    say so in ``reason`` — the old code returned the raw rounded value
    and the reshard blew up (or silently truncated) downstream."""
    from repro.distributed.fault_tolerance import plan_remesh
    plan = plan_remesh(alive_hosts=3, devices_per_host=1, model_axis=1,
                       old_hosts=4, old_global_batch=14, restore_step=None)
    assert plan.feasible
    assert plan.new_global_batch % 3 == 0, plan
    assert plan.new_global_batch in (9, 12)
    assert "snapped" in plan.reason


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 6), st.integers(1, 4), st.integers(1, 3),
       st.integers(1, 8), st.integers(1, 64))
def test_plan_remesh_feasible_plans_always_shardable_property(
        alive, dph, model_axis, old_hosts, old_gb):
    """For ANY remesh input: a feasible plan's new_global_batch is
    positive and divisible by the surviving host count (directly
    applicable to a uniform ShardedSampler split)."""
    from repro.distributed.fault_tolerance import plan_remesh
    plan = plan_remesh(alive_hosts=alive, devices_per_host=dph,
                       model_axis=model_axis, old_hosts=old_hosts,
                       old_global_batch=old_gb, restore_step=None)
    if plan.feasible:
        assert plan.new_global_batch > 0
        assert plan.new_global_batch % alive == 0


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 4), st.integers(2, 5), st.integers(1, 3),
       st.sampled_from(["host_major", "strided"]), st.integers(0, 10**6))
def test_geometry_latch_exact_coverage_property(hosts, bpe, gb_scale,
                                                layout, seed):
    """For ANY randomized (hosts, epoch shape, layout): latching a new
    global batch at an epoch boundary keeps exact once-per-epoch coverage
    in BOTH epochs, batches_per_epoch follows the schedule, and the
    schedule-aware absolute math round-trips."""
    gb0 = 12 * gb_scale                 # divisible by every host count <= 4
    n = gb0 * bpe
    gb1 = max(hosts, (gb0 * 3 // 4) // hosts * hosts)  # a smaller latch
    shards = _shards(n, gb0, hosts, chunk=0, layout=layout, seed=seed)
    for s in shards:
        eff = s.set_geometry(gb1, epoch=1)
        assert eff == 1
        assert s.gb_for_epoch(0) == gb0 and s.gb_for_epoch(1) == gb1
        assert s.batches_per_epoch(0) == bpe
        assert s.batches_per_epoch(1) == n // gb1
    for epoch, gb in ((0, gb0), (1, gb1)):
        seen = []
        for b in range(n // gb):
            for s in shards:
                seen.extend(s.local_indices(epoch, b).tolist())
        covered = n - (n % gb)          # drop_last tail at the new gb
        assert len(seen) == covered
        assert len(set(seen)) == covered
    # schedule-aware absolute position round-trips through state_at
    probe = shards[0]
    for pos in (0, bpe - 1, bpe, bpe + 1, bpe + n // gb1 - 1):
        st_ = probe.state_at(pos)
        assert probe.epoch_start(st_.epoch) + st_.batch_offset == pos


def _run_fleet_death(n, gb, hosts, *, kill, rounds_before=3, seed=7):
    """Drive a direct-mode fleet, starve ``kill`` of heartbeats, poll
    past the timeout, and return (coord, streams, delivered, agents)."""
    from repro.data import DataLoader, LoaderParams
    from repro.tuning import FleetConfig, FleetCoordinator, HostAgent
    from conftest import make_table_evaluator

    timeout = 4.0
    clock = [0.0]
    coord = FleetCoordinator(
        config=FleetConfig(heartbeat_timeout_s=timeout, warmup_steps=2,
                           cooldown_steps=8, num_cpu_cores=4, num_devices=1,
                           max_prefetch=2, retune_budget_batches=2),
        clock=lambda: clock[0])
    agents, streams = {}, {}
    for h in range(hosts):
        dl = DataLoader(make_index_dataset(n), gb, shuffle=True, seed=seed,
                        params=LoaderParams(num_workers=2,
                                            prefetch_factor=2),
                        host_index=h, host_count=hosts)
        name = f"host{h}"
        agents[name] = coord.register(HostAgent(
            name, dl, evaluator=make_table_evaluator(
                lambda i, j: 4.0 / i + 0.1 * j)))
        streams[name] = dl.stream(to_device=False)
    delivered = []
    alive = set(agents)
    for _ in range(rounds_before):
        clock[0] += 1.0
        for name in sorted(alive):
            delivered.append(next(streams[name]))
            agents[name].observe(data_s=0.001, step_s=0.05)
        coord.poll()
    alive.discard(kill)
    for _ in range(int(timeout) + 2):
        clock[0] += 1.0
        for name in sorted(alive):
            agents[name].observe(data_s=0.001, step_s=0.05)
        coord.poll()
    return coord, streams, delivered, agents, alive


def test_elastic_reshard_applies_new_global_batch_with_exact_coverage():
    """The tentpole: a 4->3 host death rescales the global batch 12->9 at
    the NEXT epoch boundary (plan_remesh keeps per-replica batch at 3).
    Epoch 0 finishes at the old geometry with exact coverage (makeup for
    the corpse's unconsumed slices), epoch 1 runs at the new geometry
    with exact coverage — and the new batch is observable in the event
    log, the sampler schedules, and the HA member mirrors."""
    gb, bpe = 12, 6
    n = gb * bpe
    coord, streams, delivered, agents, alive = _run_fleet_death(
        n, gb, 4, kill="host3")
    try:
        event = next(e for e in coord.events if e["kind"] == "reshard")
        assert event["plan"].new_global_batch == 9
        # the latch epoch is the first boundary no producer (including its
        # prefetch pipeline) has crossed yet — always in the future
        ge = event["geometry_epoch"]
        assert ge is not None and ge >= 1
        assert event["sizes"] is None            # 12 % 3 == 0: no ragged
        bpe1 = n // 9
        for name in sorted(alive):
            s = agents[name].loader.sampler
            assert s.gb_for_epoch(ge - 1) == 12 and s.gb_for_epoch(ge) == 9
        # the HA snapshot carries the schedule for a promoted standby
        members = coord.state_dict()["members"]
        for name in sorted(alive):
            sched = members[name]["spec"]["sampler"]["geometry"]
            assert [list(map(int, e)) for e in sched] == [[0, 12], [ge, 9]]
        # drain the pre-latch epochs (old geometry + makeup) plus one full
        # epoch at the NEW geometry
        for name in sorted(alive):
            s = streams[name]
            while s.position < ge * bpe + bpe1:
                delivered.append(next(s))
        flat = flat_indices(delivered)
        assert flat == sorted(list(range(n)) * (ge + 1))   # every epoch exact
        for name in sorted(alive):
            assert agents[name].loader.global_batch == 9
            assert agents[name].loader.sampler.local_batch == 3
            assert list(
                agents[name].loader.sampler.sizes_for_epoch(ge)) == [3, 3, 3]
    finally:
        for s in streams.values():
            s.close()


def test_elastic_reshard_ragged_split_regression():
    """Regression for the floor-division deal bug: global batch 8 over 3
    survivors is non-divisible — the old code computed new_local = 8//3
    and silently truncated (and the uniform reshard itself raised in the
    stream thread).  The fix deals a ragged largest-remainder split
    [3, 3, 2] with exact coverage, then latches the plan's snapped batch
    (6) at the epoch boundary."""
    gb, bpe = 8, 6
    n = gb * bpe
    coord, streams, delivered, agents, alive = _run_fleet_death(
        n, gb, 4, kill="host3")
    try:
        event = next(e for e in coord.events if e["kind"] == "reshard")
        assert list(event["sizes"]) == [3, 3, 2]
        assert event["plan"].new_global_batch == 6   # 4->3 at 2/replica
        ge = event["geometry_epoch"]
        assert ge is not None and ge >= 1
        by_shard = sorted((agents[name] for name in alive),
                          key=lambda a: a.shard_index())
        bpe1 = n // 6
        for name in sorted(alive):
            s = streams[name]
            while s.position < ge * bpe + bpe1:
                delivered.append(next(s))
        assert flat_indices(delivered) == sorted(list(range(n)) * (ge + 1))
        assert [a.loader.sampler.local_batch for a in by_shard] == [2, 2, 2]
    finally:
        for s in streams.values():
            s.close()


def test_geometry_checkpoint_roundtrip():
    """DataLoader.state_dict carries the geometry schedule AND the ragged
    shard sizes; a restored loader continues at the right epoch shape."""
    n, gb = 96, 12
    dl = DataLoader(make_index_dataset(n), gb, shuffle=True, seed=3,
                    host_index=0, host_count=3)
    assert dl.set_geometry(9, epoch=2) == 2
    dl.sampler.reshard(3, 0, sizes=[5, 4, 3])
    sd = dl.state_dict()
    dl2 = DataLoader(make_index_dataset(n), gb, shuffle=True, seed=3,
                     host_index=0, host_count=3)
    dl2.load_state_dict(sd)
    assert dl2.sampler.geometry_state() == dl.sampler.geometry_state()
    assert list(dl2.sampler.shard_sizes) == [5, 4, 3]
    assert dl2.sampler.gb_for_epoch(2) == 9
    # stale explicit sizes (sum != the latched gb) revert to even_split
    assert list(dl2.sampler.sizes_for_epoch(2)) == [3, 3, 3]


def test_nondivisible_uniform_reshard_raises_without_sizes():
    """Regression guard: the silent-truncation path is now an explicit
    error — resharding to a count that does not divide the global batch
    demands an explicit ragged split."""
    s = ShardedSampler(48, 8, host_index=0, host_count=4)
    with pytest.raises(ValueError, match="ragged"):
        s.reshard(3, 0)
    s.reshard(3, 0, sizes=[3, 3, 2])    # the explicit split is accepted
    assert s.local_batch == 3


def test_per_host_consensus_rebalances_shard_sizes():
    """consensus="per_host": heterogeneous hosts tune independently and
    the batch partition re-apportions toward the fast host — contiguous
    host-major slices, exact coverage preserved mid-epoch."""
    from repro.data import DataLoader, LoaderParams
    from repro.tuning import FleetConfig, FleetCoordinator, HostAgent
    from conftest import make_table_evaluator

    n, gb, hosts = 240, 12, 3
    clock = [0.0]
    coord = FleetCoordinator(
        config=FleetConfig(heartbeat_timeout_s=10.0, warmup_steps=2,
                           cooldown_steps=4, num_cpu_cores=4, num_devices=1,
                           max_prefetch=2, retune_budget_batches=2,
                           consensus="per_host"),
        clock=lambda: clock[0])
    agents, streams = [], []
    # host0 is 2x faster than its peers at every cell
    tables = [lambda i, j: 2.0 / i + 0.05 * j,
              lambda i, j: 4.0 / i + 0.1 * j,
              lambda i, j: 4.0 / i + 0.1 * j]
    for h in range(hosts):
        dl = DataLoader(make_index_dataset(n), gb, shuffle=True, seed=11,
                        params=LoaderParams(num_workers=2,
                                            prefetch_factor=2),
                        host_index=h, host_count=hosts)
        agents.append(coord.register(HostAgent(
            f"host{h}", dl, evaluator=make_table_evaluator(tables[h]))))
        streams.append(dl.stream(to_device=False))
    delivered = []
    try:
        for _ in range(6):
            clock[0] += 1.0
            for a, s in zip(agents, streams):
                delivered.append(next(s))
                a.observe(data_s=0.09, step_s=0.1)   # stalled: force retune
        actions = coord.poll()
        consensus = next(a for a in actions if a["kind"] == "consensus")
        assert consensus["mode"] == "per_host"
        assert consensus["applied"]
        sizes = consensus["sizes"]
        assert sizes is not None and sum(sizes) == gb
        assert sizes[0] > sizes[1]           # fast host takes the bigger slice
        # per-host cells: each host adopted its own optimum
        assert [tuple(p) for p in consensus["params"]] == \
            [a.param_cell() for a in agents]
        # the partition applies at the negotiated barrier — drain the epoch
        # (exact coverage must survive the mid-epoch repartition), then the
        # live samplers hold the new contiguous host-major slices
        for s in streams:
            while s.position < n // gb:
                delivered.append(next(s))
        assert flat_indices(delivered) == list(range(n))
        assert [a.loader.sampler.local_batch for a in agents] == sizes
    finally:
        for s in streams:
            s.close()
