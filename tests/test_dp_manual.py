"""Manual-DP runtime (distributed/dp_shard.py): numerical equivalence of the
shard_map train/serve paths against the single-device reference, plus the
regression repro for the XLA partitioner crash the gathers work around.

Subprocess tests: the 8-device mesh needs XLA_FLAGS set before jax init.
"""
import subprocess
import sys
import textwrap

import pytest

PRE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.configs.base import get_config, reduced
from repro.distributed.sharding_rules import rules_for, use_rules
from repro.models import build_model
from repro.train.train_step import TrainState, TrainStepConfig, make_train_step
from repro.train.optimizer import init_adamw
from repro.launch.dryrun import params_shardings, batch_shardings

def make_batch(cfg, B, S, seed=0):
    r = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
         "targets": jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
         "loss_mask": jnp.ones((B, S), jnp.float32)}
    return b
"""


def run_py(code: str, timeout=560):
    r = subprocess.run([sys.executable, "-c", PRE + textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       cwd=__file__.rsplit("/", 2)[0])
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    return r.stdout


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "granite-moe-3b-a800m"])
def test_manual_train_step_matches_single_device(arch):
    """One manual-DP train step on a (2,2,2) mesh == one single-device step
    (max param diff < 5e-3, driven by bf16 layout differences)."""
    out = run_py(f"""
    cfg = reduced(get_config({arch!r}))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    B, S = 16, 32
    batch = make_batch(cfg, B, S)
    scfg = TrainStepConfig(remat_policy="dots", microbatches=2)

    params = model.init(rng)
    state = TrainState(params, init_adamw(params), None)
    ref_state, ref_metrics = jax.jit(make_train_step(model, scfg))(state, batch)
    ref = jax.device_get(ref_state.params)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    import dataclasses
    scfg = dataclasses.replace(scfg, dp_manual=True)
    with use_rules(mesh, rules_for("train")) as ctx:
        params = model.init(rng)
        params = jax.device_put(params, params_shardings(model, ctx))
        state = TrainState(params, init_adamw(params), None)
        batch_d = jax.device_put(batch, batch_shardings(batch, ctx))
        new_state, metrics = jax.jit(make_train_step(model, scfg))(state, batch_d)
    got = jax.device_get(new_state.params)

    worst = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                    - np.asarray(b, np.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(ref),
                                jax.tree_util.tree_leaves(got)))
    rel_loss = abs(float(ref_metrics["loss"]) - float(metrics["loss"]))
    print("worst", worst, "dloss", rel_loss)
    assert worst < 5e-3, worst
    assert rel_loss < 0.02 * float(ref_metrics["loss"])
    assert abs(float(ref_metrics["grad_norm"]) - float(metrics["grad_norm"])) < 5e-3
    """)
    assert "worst" in out


def test_serve_prefill_decode_match_single_device():
    """Manual-wrapped prefill+decode logits == single-device logits."""
    run_py("""
    from repro.launch.dryrun import _serve_wrap
    cfg = reduced(get_config("granite-moe-3b-a800m"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 8, 16
    r = np.random.default_rng(0)
    tokens = jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    cache = model.init_cache(B, S + 4)
    ref_logits, ref_cache = jax.jit(model.prefill)(
        params, {"tokens": tokens}, cache)
    ref_dec, _ = jax.jit(model.decode_step)(
        params, ref_cache, tokens[:, :1], jnp.full((B,), S, jnp.int32))

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    with use_rules(mesh, rules_for("prefill")) as ctx:
        wrapped = _serve_wrap(model, cfg, ctx, model.prefill)
        assert wrapped is not None
        logits, cache2 = jax.jit(wrapped)(
            params, {"tokens": tokens}, model.init_cache(B, S + 4))
        dec_w = _serve_wrap(model, cfg, ctx,
                            lambda p, b, c: model.decode_step(
                                p, c, b["tokens"], b["positions"]))
        dec, _ = jax.jit(dec_w)(
            params, {"tokens": tokens[:, :1],
                     "positions": jnp.full((B,), S, jnp.int32)}, cache2)
    d1 = float(jnp.max(jnp.abs(ref_logits.astype(jnp.float32)
                               - logits.astype(jnp.float32))))
    d2 = float(jnp.max(jnp.abs(ref_dec.astype(jnp.float32)
                               - dec.astype(jnp.float32))))
    print("prefill diff", d1, "decode diff", d2)
    assert d1 < 0.05 and d2 < 0.05, (d1, d2)
    """)


def test_cast_gather_partitioner_crash_workaround():
    """Regression: differentiating convert->all_gather under a partial-manual
    mesh aborts XLA ("Invalid binary instruction opcode copy"); the
    fully-manual inner-wrap used by dp_shard.gather_leaf must not."""
    run_py("""
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.distributed.dp_shard import gather_leaf
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    D, F, B = 8, 8, 8
    w = jax.device_put(jnp.arange(float(D * F)).reshape(D, F) / 10,
                       NamedSharding(mesh, P("data", None)))
    x = jax.device_put(jnp.ones((B, D)),
                       NamedSharding(mesh, P(("pod", "data"), None)))

    def dp_body(w_loc, xb):
        def loss_fn(wl, mb):
            g = gather_leaf(wl, {0: ("data",)}, dtype=jnp.bfloat16,
                            wrap_axes=("model",))
            y = mb.astype(jnp.bfloat16) @ g
            return jnp.sum(y.astype(jnp.float32) ** 2)
        def body(acc, mb):
            return jax.tree.map(jnp.add, acc,
                                jax.grad(loss_fn)(w_loc, mb)), None
        acc, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros_like(w_loc),
                              xb.reshape(2, -1, D))
        return jax.lax.psum(acc, ("pod",))

    out = jax.jit(jax.shard_map(
        dp_body, mesh=mesh,
        in_specs=(P("data", None), P(("pod", "data"), None)),
        out_specs=P("data", None), axis_names={"pod", "data"},
        check_vma=False))(w, x)
    assert out.shape == (D, F)
    print("gather-under-grad OK")
    """)
