"""Trainer, optimizer, checkpointing, gradient compression."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataLoader, LoaderParams, token_dataset
from repro.models import build_model
from repro.train.optimizer import (AdamWConfig, adamw_update, init_adamw,
                                   lr_at)
from repro.train.train_step import (TrainStepConfig, init_train_state,
                                    make_train_step)
from repro.train.trainer import Trainer, TrainerConfig


# --------------------------------------------------------------------------
# optimizer unit tests
# --------------------------------------------------------------------------
def test_adamw_matches_reference_implementation():
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, total_steps=100,
                      schedule="constant", weight_decay=0.0,
                      grad_clip_norm=1e9, min_lr_ratio=1.0)
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, 0.2, -0.3])}
    state = init_adamw(params)
    new_p, state, _ = adamw_update(cfg, params, grads, state)

    # reference numpy adam (bias-corrected), step 1
    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.05 * np.array([0.1, 0.2, -0.3]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    expect = np.array([1.0, -2.0, 3.0]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)


def test_lr_schedule_shapes():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1, schedule="cosine")
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0)
    assert float(lr_at(cfg, 110)) == pytest.approx(0.1, abs=1e-3)
    mid = float(lr_at(cfg, 60))
    assert 0.1 < mid < 1.0


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(grad_clip_norm=1.0, warmup_steps=0,
                      schedule="constant")
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    state = init_adamw(params)
    _, _, metrics = adamw_update(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


# --------------------------------------------------------------------------
# convergence
# --------------------------------------------------------------------------
def _train(compress: bool, steps=60):
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    ds = token_dataset(64, 16, cfg.vocab_size, seed=1)
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=0), seed=1)
    tc = TrainerConfig(
        total_steps=steps, checkpoint_dir=None, autotune=False, log_every=steps,
        step_config=TrainStepConfig(
            remat_policy="none", compress_grads=compress,
            optimizer=AdamWConfig(peak_lr=3e-3, warmup_steps=5,
                                  total_steps=steps)))
    tr = Trainer(model, dl, tc)
    out = tr.run()
    return out["loss"]


def test_training_reduces_loss():
    final = _train(compress=False)
    assert final < 5.0   # from ~5.55 at init on vocab 256


def test_compressed_grads_converge_similarly():
    """Int8 EF-compression must not break optimization (beyond-paper DP
    trick)."""
    plain = _train(compress=False)
    comp = _train(compress=True)
    assert comp < 5.0
    assert abs(comp - plain) < 0.35


def test_quantize_roundtrip_error_bounded():
    from repro.distributed.grad_compress import (dequantize_int8,
                                                 quantize_int8)
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-7
    assert q.dtype == jnp.int8


def test_error_feedback_reduces_bias():
    """EF accumulates what quantization dropped: over many rounds the mean
    applied update approaches the true gradient."""
    from repro.distributed.grad_compress import compress_decompress
    g = jnp.array([1e-4, 5e-3, 1.0])   # tiny grads get crushed by scale 1.0
    err = jnp.zeros(3)
    applied = jnp.zeros(3)
    for _ in range(200):
        out, err = compress_decompress(g, err)
        applied = applied + out
    # quantization bin is max|g|/127 ~ 0.008; EF drives the *average*
    # applied update to the true gradient within a fraction of one bin.
    np.testing.assert_allclose(np.asarray(applied / 200), np.asarray(g),
                               rtol=0.05, atol=1e-4)


# --------------------------------------------------------------------------
# trainer + checkpoint restart
# --------------------------------------------------------------------------
def test_checkpoint_restart_resumes_exactly(tmp_path):
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    ds = token_dataset(64, 16, cfg.vocab_size, seed=2)
    mk = lambda: DataLoader(ds, 8, params=LoaderParams(num_workers=0), seed=2)
    tc = lambda steps: TrainerConfig(
        total_steps=steps, checkpoint_every=5, log_every=5,
        checkpoint_dir=str(tmp_path), autotune=False,
        step_config=TrainStepConfig(
            remat_policy="none",
            optimizer=AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                  total_steps=20)))

    # run 1: 10 steps straight through
    t1 = Trainer(model, mk(), tc(10))
    t1.run()
    p_straight = t1.state.params

    # run 2: crash at 5 (simulated by stopping), restart to 10
    import shutil
    shutil.rmtree(tmp_path)
    os.makedirs(tmp_path)
    t2a = Trainer(model, mk(), tc(5))
    t2a.run()
    t2b = Trainer(model, mk(), tc(10))
    t2b.run()
    assert t2b.start_step == 5

    for a, b in zip(jax.tree_util.tree_leaves(p_straight),
                    jax.tree_util.tree_leaves(t2b.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_trainer_autotune_sets_loader_params(tmp_path):
    cfg = reduced(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    ds = token_dataset(64, 16, cfg.vocab_size, seed=0)
    dl = DataLoader(ds, 8, seed=0)
    tc = TrainerConfig(total_steps=4, autotune=True,
                       autotune_budget_batches=2, autotune_max_prefetch=2,
                       dpt_cache_path=str(tmp_path / "dpt.json"),
                       log_every=2,
                       step_config=TrainStepConfig(
                           remat_policy="none",
                           optimizer=AdamWConfig(total_steps=4)))
    tr = Trainer(model, dl, tc)
    tr.run()
    assert dl.params.num_workers >= 1
    # second trainer reuses the cached result without re-measuring
    dl2 = DataLoader(ds, 8, seed=0)
    tr2 = Trainer(model, dl2, tc)
    params = tr2.tune_loader()
    assert (params.num_workers, params.prefetch_factor) == \
        (dl.params.num_workers, dl.params.prefetch_factor)
