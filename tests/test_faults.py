"""The fault-tolerant data plane (DESIGN.md §10): storage fault
injection, retrying reads, sample quarantine, worker-crash recovery and
brownout degraded mode."""
import os
import pickle
import signal
import time

import numpy as np
import pytest

from conftest import flat_indices, make_cold_dataset, make_index_dataset
from repro.data import (BrownoutError, CorruptSampleError, DataLoader,
                        Dataset, FaultPolicy, FaultStats, FaultyStorage,
                        LoaderParams, QuarantineLog, RetryPolicy,
                        ShardedSampler, StorageFaultSpec, TransientReadError,
                        quarantine_complement)
from repro.data.storage import ArrayStorage
from repro.data.worker_pool import ProcessWorkerPool, ThreadWorkerPool

RETRY_FAST = dict(retry_attempts=3, retry_backoff_s=1e-3,
                  retry_deadline_s=2.0)


def _ident(a):
    # module-level (picklable) index transform for process-pool tests
    return {"x": a}


def make_faulty_index_dataset(n, spec, *, width=4):
    items = [np.full((width,), i, np.int32) for i in range(n)]
    return Dataset(FaultyStorage(ArrayStorage(items), spec),
                   transform=_ident)


# ---- FaultyStorage ----------------------------------------------------------

def test_faulty_storage_deterministic_and_picklable():
    spec = StorageFaultSpec(transient_rate=0.3, corrupt_items=(5,), seed=7)
    items = [np.full((4,), i, np.int32) for i in range(32)]

    def failures(storage):
        seen = []
        for i in range(32):
            try:
                storage.read(i)
                seen.append("ok")
            except CorruptSampleError:
                seen.append("corrupt")
            except TransientReadError:
                seen.append("transient")
        return seen

    a = failures(FaultyStorage(ArrayStorage(items), spec))
    b = failures(FaultyStorage(ArrayStorage(items), spec))
    assert a == b                       # pure-hash draws: replayable
    assert a[5] == "corrupt"
    assert "transient" in a
    # a transient clears on retry eventually (attempt-keyed draws)
    s = FaultyStorage(ArrayStorage(items), spec)
    bad = next(i for i, kind in enumerate(a) if kind == "transient")
    got = None
    for _ in range(64):
        try:
            got = s.read(bad)
            break
        except TransientReadError:
            continue
    assert got is not None and int(got[0]) == bad
    # picklable (locks remint) with counters preserved
    s2 = pickle.loads(pickle.dumps(s))
    assert s2.counters() == s.counters()
    np.testing.assert_array_equal(s2.read(0), items[0])


def test_faulty_storage_brownout_window():
    spec = StorageFaultSpec(brownout=(2, 4))   # 0-based accesses [2, 4)
    s = FaultyStorage(ArrayStorage(
        [np.zeros((2,), np.int32) for _ in range(8)]), spec)
    s.read(0)                           # access 0: before the window
    s.read(1)                           # access 1: before the window
    with pytest.raises(BrownoutError):
        s.read(2)                       # access 2: inside
    with pytest.raises(BrownoutError):
        s.read_batch([3, 4])            # access 3: inside
    s.read(5)                           # access 4: window passed
    assert s.brownout_raised == 2


def test_retry_policy_backoff_deterministic_and_bounded():
    r = RetryPolicy(backoff_s=0.01, backoff_mult=2.0, backoff_max_s=0.05,
                    jitter=0.5, seed=3)
    a = [r.sleep_s(k, key=9) for k in range(1, 8)]
    b = [r.sleep_s(k, key=9) for k in range(1, 8)]
    assert a == b                       # deterministic jitter
    assert all(0 < s <= 0.05 * 1.25 for s in a)
    assert a[1] > a[0]                  # exponential before the cap


# ---- retries / quarantine through the loader --------------------------------

def test_loader_retries_transients_to_full_coverage():
    ds = make_cold_dataset(96, latency_s=0.0, fault_rate=0.2, fault_seed=11)
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=2, **RETRY_FAST),
                    shuffle=False, seed=0)
    got = list(dl.host_batches(epoch=0))
    assert len(got) == 96 // 8          # transient faults: nothing lost
    assert ds.storage.faults_injected > 0
    assert dl.fault_stats.read_retries > 0
    assert len(dl.quarantine) == 0
    io = dl.io_counters()
    assert io["read_retries"] >= 1 and io["quarantined"] == 0


def test_corrupt_items_quarantined_under_skip():
    n, bad = 64, (3, 17, 42)
    ds = make_faulty_index_dataset(n, StorageFaultSpec(corrupt_items=bad))
    dl = DataLoader(ds, 8, params=LoaderParams(
        num_workers=2, on_bad_sample="skip", **RETRY_FAST),
        shuffle=False, seed=0)
    got = list(dl.host_batches(epoch=0))
    assert flat_indices(got) == \
        quarantine_complement(n, dl.quarantine).tolist()
    assert sorted(dl.quarantine.ids().tolist()) == sorted(bad)
    assert all("corrupt" in r for r in dl.quarantine.reasons().values())
    # quarantined ids exit cost tracking (their EWMA slots reset)
    slots = dl.cost_tracker._slots(list(bad))
    assert np.isnan(dl.cost_tracker._ewma[slots]).all()
    io = dl.io_counters()
    assert io["quarantined"] == len(bad)
    # the NEXT epoch never touches them again (screened up front)
    before = ds.storage.corrupt_raised
    got2 = list(dl.host_batches(epoch=1))
    assert flat_indices(got2) == \
        quarantine_complement(n, dl.quarantine).tolist()
    assert ds.storage.corrupt_raised == before


def test_substitute_completes_batches_deterministically():
    n, bad = 64, (5, 20)
    params = LoaderParams(num_workers=2, on_bad_sample="substitute",
                          **RETRY_FAST)

    def run():
        ds = make_faulty_index_dataset(
            n, StorageFaultSpec(corrupt_items=bad))
        dl = DataLoader(ds, 8, params=params, shuffle=False, seed=0)
        return [np.asarray(b["x"])[:, 0].tolist()
                for b in dl.host_batches(epoch=0)], dl

    got, dl = run()
    got2, _ = run()
    assert got == got2                  # seeded substitution: replayable
    flat = [i for b in got for i in b]
    assert len(flat) == n               # batch sizes preserved
    assert not set(bad) & set(flat)     # corrupt ids replaced
    assert set(flat) <= set(range(n))
    assert sorted(dl.quarantine.ids().tolist()) == sorted(bad)


def test_corrupt_raise_mode_propagates():
    ds = make_faulty_index_dataset(32, StorageFaultSpec(corrupt_items=(9,)))
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=2, **RETRY_FAST),
                    shuffle=False, seed=0)
    with pytest.raises(CorruptSampleError):
        list(dl.host_batches(epoch=0))
    assert 9 in dl.quarantine           # the log still names the culprit


def test_poisoned_transform_contained_under_skip():
    n = 64

    def poison(a):
        if (a == 7).any():
            raise ValueError("poisoned sample 7")
        return {"x": a}

    ds = make_index_dataset(n, transform=poison)
    dl = DataLoader(ds, 8, params=LoaderParams(
        num_workers=2, on_bad_sample="skip", **RETRY_FAST),
        shuffle=False, seed=0)
    got = list(dl.host_batches(epoch=0))
    assert flat_indices(got) == [i for i in range(n) if i != 7]
    assert 7 in dl.quarantine
    assert "poisoned" in dl.quarantine.reasons()[7]
    # legacy default (raise) stays pool-fatal for non-IO exceptions
    ds2 = make_index_dataset(n, transform=poison)
    dl2 = DataLoader(ds2, 8, params=LoaderParams(num_workers=2),
                     shuffle=False, seed=0)
    with pytest.raises(ValueError, match="poisoned"):
        list(dl2.host_batches(epoch=0))


def test_stream_skip_accounting_with_preseeded_quarantine():
    n, gb = 64, 8
    ds = make_index_dataset(n)
    dl = DataLoader(ds, gb, params=LoaderParams(
        num_workers=2, on_bad_sample="skip", **RETRY_FAST),
        shuffle=False, seed=0)
    for i in range(gb):                 # batch 0 entirely quarantined
        dl.quarantine.add(i, "operator")
    stream = dl.stream(to_device=False)
    per_epoch = n // gb
    it = iter(stream)
    got = [next(it) for _ in range(per_epoch - 1)]
    assert flat_indices(got) == list(range(gb, n))
    # the skipped slot consumed its position: the cursor reached epoch end
    assert stream.position == per_epoch
    stream.close()


# ---- worker-crash containment ----------------------------------------------

def test_process_pool_survives_sigkill_and_completes_epoch():
    n, gb = 192, 8
    ds = make_index_dataset(n, transform=_ident)
    idx = ShardedSampler(n, gb, shuffle=False, seed=0).epoch_iter(0)
    pool = ProcessWorkerPool(ds, idx, num_workers=2, prefetch_factor=2,
                             ordered=True)
    got = []
    it = iter(pool)
    got.append(next(it))
    os.kill(sorted(pool._worker_pids)[0], signal.SIGKILL)
    for b in it:
        got.append(b)
    assert flat_indices(got) == list(range(n))   # nothing lost, nothing dup
    assert pool.resubmits >= 1


def test_process_pool_shutdown_after_worker_death_does_not_hang():
    n, gb = 256, 8
    ds = make_index_dataset(n, transform=_ident)
    idx = ShardedSampler(n, gb, shuffle=False, seed=0).epoch_iter(0)
    pool = ProcessWorkerPool(ds, idx, num_workers=2, prefetch_factor=2,
                             ordered=True)
    it = iter(pool)
    next(it)
    os.kill(sorted(pool._worker_pids)[-1], signal.SIGKILL)
    t0 = time.perf_counter()
    pool.shutdown()
    assert time.perf_counter() - t0 < 5.0


def test_process_pool_quarantine_merges_to_parent():
    n, bad = 96, (10, 33)
    ds = make_faulty_index_dataset(n, StorageFaultSpec(corrupt_items=bad))
    dl = DataLoader(ds, 8, params=LoaderParams(
        num_workers=2, use_processes=True, on_bad_sample="skip",
        **RETRY_FAST), shuffle=False, seed=0)
    got = list(dl.host_batches(epoch=0))
    assert flat_indices(got) == [i for i in range(n) if i not in bad]
    # children shipped their tallies back: the PARENT log/stats moved
    assert sorted(dl.quarantine.ids().tolist()) == sorted(bad)
    assert dl.fault_stats.read_faults > 0
    io = dl.io_counters()
    assert io["quarantined"] == len(bad)


# ---- degraded mode ----------------------------------------------------------

def test_fault_stats_degraded_hysteresis():
    flips = []
    fs = FaultStats(degraded_enter=0.5, on_degraded=flips.append)
    for _ in range(8):
        fs.note_fault()
    assert fs.degraded and flips == [True]
    assert fs.degraded_enters == 1
    # exit needs the rate back under a quarter of the enter threshold
    for _ in range(FaultStats.WINDOW):
        fs.note_ok()
    assert not fs.degraded and flips == [True, False]
    assert fs.fault_rate() == 0.0


def test_brownout_degrades_and_heals_through_loader():
    n, gb = 1024, 8
    ds = make_cold_dataset(n, latency_s=0.0, brownout=(3, 12))
    dl = DataLoader(ds, gb, params=LoaderParams(
        num_workers=2, cache_budget_bytes=1 << 16,
        degraded_fault_rate=0.3, **RETRY_FAST), shuffle=False, seed=0)
    got = list(dl.host_batches(epoch=0))
    assert len(got) == n // gb          # brownout ridden out, nothing lost
    assert dl.fault_stats.degraded_enters >= 1
    assert not dl.fault_stats.degraded  # healed by epoch end
    assert dl.quarantine is not None and len(dl.quarantine) == 0
    tier = dl._cache_tier
    assert tier is not None and tier.read_only is False


# ---- checkpointing ----------------------------------------------------------

def test_quarantine_log_state_roundtrip():
    q = QuarantineLog()
    q.add(4, "corrupt")
    q.add(9, "retries-exhausted")
    q2 = QuarantineLog()
    q2.load_state_dict(q.state_dict())
    assert q2.ids().tolist() == [4, 9]
    assert q2.reasons() == q.reasons()
    assert 4 in q2 and 5 not in q2
    q3 = pickle.loads(pickle.dumps(q))
    assert q3.ids().tolist() == [4, 9]


# ---- the retune trigger -----------------------------------------------------

def test_goodput_monitor_fault_trigger_and_heal_oneshot():
    from repro.tuning.online import (GoodputMonitor, OnlineTunerConfig,
                                     RetunePolicy)
    cfg = OnlineTunerConfig(fault_rate_trigger=0.2)
    pol = RetunePolicy(cfg)
    mon = GoodputMonitor(window=4)
    for _ in range(4):
        mon.observe(data_s=0.0, step_s=1.0)   # zero stall
    assert not pol.drifted(mon)
    mon.note_faults(0.5, True)                # excursion
    assert pol.drifted(mon)
    mon.note_faults(0.0, False)               # heal: one-shot edge
    assert mon.fault_healed and pol.drifted(mon)
    mon.reset()                               # consumed by the retune
    assert not mon.fault_healed and not pol.drifted(mon)
    # disabled trigger never fires on faults
    off = RetunePolicy(OnlineTunerConfig())
    mon2 = GoodputMonitor(window=4)
    mon2.note_faults(1.0, True)
    assert not off.drifted(mon2)


def test_fleet_fault_consensus_edges():
    from repro.tuning.fleet import FleetConfig, FleetCoordinator, HostReport

    def report(host, fault_rate, degraded):
        return HostReport(
            host=host, steps=10, consumed=0, position=0, stall_ratio=0.0,
            steps_per_s=1.0, batch_seconds=[], params=(1, 2),
            io={"fault_rate": fault_rate, "degraded": degraded},
            makeup_done=0)

    coord = FleetCoordinator(config=FleetConfig(fault_rate_trigger=0.2))
    coord.registry.beat("h0")
    coord.reports["h0"] = report("h0", 0.0, 0.0)
    assert coord._fault_reason() is None
    coord.reports["h0"] = report("h0", 0.5, 1.0)
    assert coord.fleet_fault_rate() == 0.5 and coord.fleet_degraded()
    assert coord._fault_reason() == "fault-drift"
    assert coord._fault_reason() is None      # edge, not level
    coord.reports["h0"] = report("h0", 0.0, 0.0)
    assert coord._fault_reason() == "fault-heal"
    assert coord._fault_reason() is None
