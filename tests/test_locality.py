"""IO-locality fast path: chunked sampling quality, the DPT locality
axis, the ONLINE locality loop (retune sweep + adaptive controller,
DESIGN.md §6), the pinned staging-buffer pool, counter surfacing, and the
FileStorage fork hygiene fix (DESIGN.md §5).

Coverage/permutation invariants across randomized (chunk, shard count,
reshard, checkpoint) configurations live in test_properties.py — the
hand-enumerated case lists that used to sit here were replaced by that
property suite.
"""
import dataclasses
import multiprocessing as mp
import os

import numpy as np
import pytest

from conftest import make_cold_dataset as _cold_dataset

from repro.core.cache import DPTCache
from repro.core.dpt import DPTConfig, DPTResult, Trial
from repro.core.evaluators import LoaderEvaluator, SimulatorEvaluator
from repro.core.simulator import LoaderSimulator, MachineProfile
from repro.data import (DataLoader, FileStorage, LoaderParams,
                        ShardedSampler, coco_profile,
                        synthetic_image_dataset)
from repro.data.prefetcher import DevicePrefetcher, StagingPool
from repro.data.storage import coalesce_runs, storage_io_counters
from repro.tuning import tune


def test_chunked_batches_coalesce_into_runs():
    # chunk == global batch, one host: every batch is one contiguous run
    s = ShardedSampler(256, 32, seed=1, locality_chunk=32)
    for b in range(s.batches_per_epoch()):
        runs = coalesce_runs(s.local_indices(0, b))
        assert len(runs) == 1 and runs[0][1] == 32
    # random order: batches are essentially all singleton runs
    r = ShardedSampler(256, 32, seed=1)
    assert len(coalesce_runs(r.local_indices(0, 0))) > 24


# --------------------------------------------------------------------------
# shuffle quality: chunk order uniform, adjacency bounded
# --------------------------------------------------------------------------
def test_chunk_order_is_uniform():
    """Each chunk should land in each chunk-slot equally often across
    epochs (the chunk permutation is an unbiased rng.permutation)."""
    n, chunk = 64, 16                       # 4 chunks
    s = ShardedSampler(n, 16, seed=3, locality_chunk=chunk)
    epochs = 400
    counts = np.zeros((4, 4), int)          # chunk id x slot
    for e in range(epochs):
        perm = s._epoch_perm(e)
        for slot in range(4):
            counts[perm[slot * chunk] // chunk, slot] += 1
    expected = epochs / 4
    assert (np.abs(counts - expected) < 0.4 * expected).all(), counts


def test_adjacent_pair_rate_bounded_by_chunk_ceiling():
    from benchmarks.bench_locality import adjacent_pair_ceiling
    n = 4096
    for chunk in (16, 64, 256):
        perm = ShardedSampler(n, 64, seed=0,
                              locality_chunk=chunk)._epoch_perm(0)
        rate = float(np.mean(perm[1:] == perm[:-1] + 1))
        assert rate <= adjacent_pair_ceiling(chunk)
        assert rate < 0.2                   # nowhere near sequential (1.0)


# --------------------------------------------------------------------------
# chunked epoch == random epoch, as a sample multiset
# --------------------------------------------------------------------------
def test_chunked_epoch_is_byte_identical_multiset():
    ds = synthetic_image_dataset(128, 8, seed=0)

    def digests(chunk):
        dl = DataLoader(ds, 16, params=LoaderParams(locality_chunk=chunk),
                        shuffle=True, seed=0)
        out = []
        for batch in dl.host_batches(epoch=0, num_batches=8):
            out.extend(r.tobytes() for r in np.asarray(batch["image"]))
        return sorted(out)

    assert digests(0) == digests(32)


# --------------------------------------------------------------------------
# epoch-latched locality changes + live hot swap
# --------------------------------------------------------------------------
def test_set_locality_defers_to_next_epoch_midepoch():
    s = ShardedSampler(64, 8, seed=1)
    it = iter(s)
    first = [next(it) for _ in range(3)]            # mid-epoch now
    before = s._epoch_perm(0).copy()
    s.set_locality(8)
    assert s.chunk_for_epoch(0) == 0                # current epoch untouched
    assert s.chunk_for_epoch(1) == 8
    np.testing.assert_array_equal(s._epoch_perm(0), before)
    # at an epoch boundary the change is immediate
    s2 = ShardedSampler(64, 8, seed=1)
    s2.set_locality(8)
    assert s2.chunk_for_epoch(0) == 8
    del first


def test_hot_swap_locality_on_live_stream_zero_lost_dup():
    ds = synthetic_image_dataset(96, 8, seed=0)
    dl = DataLoader(ds, 16, params=LoaderParams(num_workers=2),
                    shuffle=True, seed=0)
    bpe = dl.sampler.batches_per_epoch()            # 6
    stream = dl.stream(to_device=False)
    seen = [next(stream) for _ in range(2)]         # mid-epoch 0
    dl.apply_params(dl.params.replace(locality_chunk=16, num_workers=1))
    # consume the rest of epoch 0 and all of epoch 1
    seen += [next(stream) for _ in range(2 * bpe - 2)]
    assert stream.swaps == 1
    assert stream.position == 2 * bpe
    assert dl.sampler.chunk_for_epoch(0) == 0       # epoch 0 kept its order
    assert dl.sampler.chunk_for_epoch(1) == 16
    # every epoch's delivered multiset is exact (no lost/dup batches)
    rows = [r.tobytes() for b in seen[:bpe] for r in np.asarray(b["image"])]
    rows2 = [r.tobytes() for b in seen[bpe:] for r in np.asarray(b["image"])]
    ref = sorted(ds.get_batch(np.arange(96), fast=False)["image"]
                 [i].tobytes() for i in range(96))
    assert sorted(rows) == ref and sorted(rows2) == ref
    stream.close()


def test_locality_schedule_survives_checkpoint_roundtrip():
    ds = synthetic_image_dataset(64, 8, seed=0)
    dl = DataLoader(ds, 8, params=LoaderParams(), shuffle=True, seed=0)
    it = iter(dl.sampler)
    for _ in range(3):
        next(it)
    dl.apply_params(dl.params.replace(locality_chunk=8))  # deferred
    state = dl.state_dict()

    dl2 = DataLoader(ds, 8, params=LoaderParams(), shuffle=True, seed=0)
    dl2.load_state_dict(state)
    assert dl2.params.locality_chunk == 8
    assert dl2.sampler.chunk_for_epoch(0) == 0      # deferral preserved
    assert dl2.sampler.chunk_for_epoch(1) == 8
    np.testing.assert_array_equal(dl2.sampler._epoch_perm(0),
                                  dl.sampler._epoch_perm(0))


# --------------------------------------------------------------------------
# the DPT third axis
# --------------------------------------------------------------------------
def test_grid_without_locality_axis_never_passes_kwarg():
    calls = []

    def ev(i, j, *, num_batches, epoch):            # no locality kwarg
        calls.append((i, j))
        from repro.data.loader import TransferStats
        return TransferStats(1.0 / (i + j), num_batches, 0)

    res = tune(evaluator=ev, strategy="grid",
               config=DPTConfig(num_cpu_cores=2, num_devices=1,
                                max_prefetch=2, num_batches=4),
               measure_default=False)
    assert calls and res.locality_chunk == 0


def test_grid_selects_chunked_on_cold_cache_real_loader():
    ds = _cold_dataset(256)
    dl = DataLoader(ds, 32, params=LoaderParams(fast_path=True),
                    shuffle=True, seed=0)
    cfg = DPTConfig(num_cpu_cores=2, num_devices=2, min_prefetch=1,
                    max_prefetch=1, num_batches=6, epoch=0,
                    locality_chunks=(0, 32))
    res = tune(evaluator=LoaderEvaluator(dl, to_device=False),
               strategy="grid", config=cfg, measure_default=False)
    assert res.locality_chunk == 32
    assert {t.locality_chunk for t in res.trials} == {0, 32}
    # measurement-only override: the live schedule never saw the sweep
    assert dl.sampler.chunk_for_epoch(0) == 0


def test_grid_selects_chunked_on_cold_cache_simulator():
    sim = LoaderSimulator(coco_profile(80), MachineProfile())
    ev = SimulatorEvaluator(sim, batch_size=64)
    cfg = DPTConfig(num_cpu_cores=4, num_devices=2, max_prefetch=2,
                    num_batches=8, epoch=0, locality_chunks=(0, 64))
    res = tune(evaluator=ev, strategy="grid", config=cfg,
               measure_default=False)
    assert res.locality_chunk == 64


def test_simulator_locality_neutral_default_and_cold_win():
    sim = LoaderSimulator(coco_profile(80), MachineProfile())
    kw = dict(batch_size=64, num_batches=8, nworker=4, nprefetch=2)
    base = sim.simulate(**kw)
    assert sim.simulate(**kw, locality_chunk=0).seconds == base.seconds
    assert sim.simulate(**kw, locality_chunk=1).seconds == base.seconds
    assert sim.simulate(**kw, locality_chunk=64).seconds < base.seconds


def test_dpt_cache_roundtrips_locality(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = DPTCache(path)
    res = DPTResult(4, 2, 1.0, [Trial(4, 2, 1.0, locality_chunk=64)],
                    locality_chunk=64)
    cache.put("m", "d", 32, res)
    assert cache.get("m", "d", 32) == (4, 2)        # legacy shape intact
    assert cache.get_params("m", "d", 32) == (4, 2, 64)
    assert DPTCache(path).get_params(
        "m", "d", 32, require_locality=True) == (4, 2, 64)
    # an entry from a two-axis sweep must not satisfy a three-axis run
    cache.put("m", "d2", 32, DPTResult(4, 2, 1.0, [Trial(4, 2, 1.0)]))
    assert cache.get_params("m", "d2", 32) == (4, 2, 0)
    assert cache.get_params("m", "d2", 32, require_locality=True) is None


def test_trainer_locality_axis_ignored_on_sharded_fleet():
    """Per-host tuned chunks would give hosts different permutations —
    the startup tune must drop the axis when the sampler is sharded."""
    from repro.train.trainer import Trainer, TrainerConfig
    ds = synthetic_image_dataset(64, 8, seed=0)
    dl = DataLoader(ds, 8, params=LoaderParams(), shuffle=True, seed=0,
                    host_index=0, host_count=2)
    cfg = TrainerConfig(autotune=True,
                        autotune_locality_chunks=(0, 16),
                        autotune_budget_batches=2, autotune_max_prefetch=1)
    tr = Trainer.__new__(Trainer)          # tune_loader only needs these
    tr.loader, tr.cfg = dl, cfg
    params = tr.tune_loader(force=True)
    assert params.locality_chunk == 0      # axis dropped, not searched


# --------------------------------------------------------------------------
# the online locality loop (DESIGN.md §6)
# --------------------------------------------------------------------------
def test_online_retune_converges_to_grid_optimal_chunk_real_loader():
    """Acceptance: an online retune started with a deliberately bad
    locality_chunk (0 = random on cold seek-bound storage) converges to
    the grid-optimal chunk WITHOUT restarting the live stream."""
    from repro.tuning import OnlineTuner, OnlineTunerConfig
    ds = _cold_dataset(256, latency_s=1e-3)
    dl = DataLoader(ds, 32, params=LoaderParams(num_workers=1,
                                                prefetch_factor=1),
                    shuffle=True, seed=0)
    bpe = dl.sampler.batches_per_epoch()            # 8
    stream = dl.stream(to_device=False)
    seen = [next(stream) for _ in range(2)]         # live, mid-epoch 0

    cfg = OnlineTunerConfig(num_cpu_cores=2, num_devices=2, max_prefetch=1,
                            retune_budget_batches=4,
                            locality_chunks=(0, 32))
    tuner = OnlineTuner(dl, evaluator=LoaderEvaluator(dl, to_device=False),
                        config=cfg, machine_fp="m", dataset_fp="d")
    params = tuner.force_retune()
    assert params is not None and params.locality_chunk == 32
    assert tuner.retunes == 1
    assert tuner.history[-1]["locality_chunk"] == 32

    # the grid (same axis, same budget) agrees: the retune converged to
    # the grid-optimal chunk
    grid = tune(evaluator=LoaderEvaluator(dl, to_device=False),
                strategy="grid",
                config=DPTConfig(num_cpu_cores=2, num_devices=2,
                                 max_prefetch=1, num_batches=4,
                                 locality_chunks=(0, 32)),
                measure_default=False)
    assert grid.locality_chunk == params.locality_chunk

    # the stream was never rebuilt: the swap latches mid-flight, epoch 0
    # keeps its order and the chunk engages at the next epoch boundary
    seen += [next(stream) for _ in range(2 * bpe - 2)]
    assert stream.swaps == 1
    assert dl.sampler.chunk_for_epoch(0) == 0
    assert dl.sampler.locality_chunk == 32
    # epoch 0's delivered multiset is exact despite the mid-epoch swap
    rows = [r.tobytes() for b in seen[:bpe] for r in np.asarray(b["image"])]
    all_images = ds.get_batch(np.arange(256), fast=False)["image"]
    ref = sorted(all_images[i].tobytes() for i in range(256))
    assert sorted(rows) == ref
    stream.close()


def test_online_retune_converges_to_grid_optimal_chunk_simulator():
    """Same convergence through the virtual-time evaluator: the online
    sweep resolves the locality axis exactly where the grid does."""
    from repro.tuning import OnlineTuner, OnlineTunerConfig
    sim = LoaderSimulator(coco_profile(80), MachineProfile())
    ds = synthetic_image_dataset(64, 8, seed=0)
    dl = DataLoader(ds, 64, params=LoaderParams(num_workers=4,
                                                prefetch_factor=2),
                    shuffle=True, seed=0)
    cfg = OnlineTunerConfig(num_cpu_cores=4, num_devices=2, max_prefetch=2,
                            retune_budget_batches=8, strategy="grid",
                            locality_chunks=(0, 64))
    tuner = OnlineTuner(dl, evaluator=SimulatorEvaluator(sim, batch_size=64),
                        config=cfg, machine_fp="m", dataset_fp="d")
    params = tuner.force_retune()
    assert params is not None and params.locality_chunk == 64

    grid = tune(evaluator=SimulatorEvaluator(sim, batch_size=64),
                strategy="grid",
                config=DPTConfig(num_cpu_cores=4, num_devices=2,
                                 max_prefetch=2, num_batches=8,
                                 locality_chunks=(0, 64)),
                measure_default=False)
    assert grid.locality_chunk == 64 == params.locality_chunk


def test_online_retune_keeps_good_chunk():
    """Anti-churn: when the current chunk is already optimal, the sweep
    must not thrash it (and a no-win retune backs off as before)."""
    from repro.tuning import OnlineTuner, OnlineTunerConfig
    ds = _cold_dataset(128, latency_s=5e-4)
    dl = DataLoader(ds, 32, params=LoaderParams(num_workers=1,
                                                prefetch_factor=1,
                                                locality_chunk=32),
                    shuffle=True, seed=0)
    cfg = OnlineTunerConfig(num_cpu_cores=2, num_devices=2, max_prefetch=1,
                            retune_budget_batches=4,
                            locality_chunks=(0, 32))
    tuner = OnlineTuner(dl, evaluator=LoaderEvaluator(dl, to_device=False),
                        config=cfg, machine_fp="m", dataset_fp="d")
    assert tuner.force_retune() is None
    assert dl.params.locality_chunk == 32


def test_adaptive_controller_triggers_resize_on_run_len_collapse():
    """Acceptance: the adaptive controller proposes a resize when the
    live coalesced_run_len falls below half the active chunk — applied as
    an epoch-latched hot swap on the live stream."""
    from repro.tuning import (AdaptiveLocalityConfig,
                              AdaptiveLocalityController)
    ds = synthetic_image_dataset(96, 8, seed=0)
    dl = DataLoader(ds, 16, params=LoaderParams(num_workers=1,
                                                locality_chunk=16),
                    shuffle=True, seed=0)
    stream = dl.stream(to_device=False)
    next(stream)                                    # live, mid-epoch
    ctl = AdaptiveLocalityController(
        dl, AdaptiveLocalityConfig(patience=2, min_requests=4,
                                   cooldown_steps=0))
    # counters: healthy window first (run_len 16 = the chunk), then the
    # cache warms / topology changes and runs collapse to ~5 (< 8 = C/2)
    io = {"coalesced_requests": 10, "reads": 160, "cache_hits": 0}
    assert ctl.observe(dict(io)) is None            # baseline snapshot
    io = {"coalesced_requests": 20, "reads": 320, "cache_hits": 0}
    assert ctl.observe(dict(io)) is None            # healthy: run 16
    io = {"coalesced_requests": 30, "reads": 420, "cache_hits": 50}
    assert ctl.observe(dict(io)) is None            # low window 1 (run 5)
    io = {"coalesced_requests": 40, "reads": 520, "cache_hits": 100}
    proposal = ctl.observe(dict(io))                # low window 2 -> fire
    assert proposal == 4                            # 2^floor(log2(5))
    assert ctl.proposals == 1
    assert dl.params.locality_chunk == 4
    # epoch-latched on the live stream: current epoch keeps its order
    for _ in range(8):
        next(stream)
    assert stream.swaps == 1
    assert dl.sampler.chunk_for_epoch(0) == 16
    assert dl.sampler.locality_chunk == 4
    stream.close()


def test_adaptive_controller_healthy_run_never_fires():
    from repro.tuning import (AdaptiveLocalityConfig,
                              AdaptiveLocalityController)
    ds = synthetic_image_dataset(32, 8, seed=0)
    dl = DataLoader(ds, 8, params=LoaderParams(locality_chunk=8),
                    shuffle=True, seed=0)
    ctl = AdaptiveLocalityController(
        dl, AdaptiveLocalityConfig(patience=1, min_requests=4,
                                   cooldown_steps=0))
    ctl.observe({"coalesced_requests": 10, "reads": 80, "cache_hits": 0})
    for k in range(2, 6):       # run length stays ~8 = the chunk
        out = ctl.observe({"coalesced_requests": 10 * k,
                           "reads": 80 * k, "cache_hits": 0})
        assert out is None
    assert ctl.proposals == 0
    assert dl.params.locality_chunk == 8


def test_adaptive_controller_routes_to_fleet_not_local():
    """On a sharded fleet the controller must never change locality
    locally — the proposal routes to on_propose (the coordinator)."""
    from repro.tuning import (AdaptiveLocalityConfig,
                              AdaptiveLocalityController)
    ds = synthetic_image_dataset(64, 8, seed=0)
    dl = DataLoader(ds, 16, params=LoaderParams(locality_chunk=16),
                    shuffle=True, seed=0, host_index=0, host_count=2)
    routed = []
    ctl = AdaptiveLocalityController(
        dl, AdaptiveLocalityConfig(patience=1, min_requests=4,
                                   cooldown_steps=0),
        on_propose=routed.append)
    ctl.observe({"coalesced_requests": 10, "reads": 160, "cache_hits": 0})
    ctl.observe({"coalesced_requests": 20, "reads": 260, "cache_hits": 50})
    assert routed == [4]                            # run 50/10 -> snap 4
    assert dl.params.locality_chunk == 16           # untouched locally


def test_fleet_locality_reconsensus_uniform_push(fleet_factory):
    """The fleet path: re-consensus sweeps the locality axis uniformly,
    pushes the winner to every host, and pins ONE common latch epoch."""
    from repro.tuning import FleetConfig

    def fn(i, j, chunk):
        return (4.0 / i + 0.1 * j) * (0.4 if chunk == 8 else 1.0)

    fleet = fleet_factory(
        config=FleetConfig(heartbeat_timeout_s=5.0, warmup_steps=2,
                           cooldown_steps=4, num_cpu_cores=4, num_devices=1,
                           max_prefetch=2, retune_budget_batches=2,
                           locality_chunks=(0, 8)))
    for a in fleet.agents:
        from conftest import make_table_evaluator
        a.evaluator = make_table_evaluator(fn, locality=True)
    fleet.coord.request_consensus(reason="forced")
    actions = fleet.coord.poll()
    consensus = next(a for a in actions if a["kind"] == "consensus")
    assert consensus["applied"] and consensus["locality_chunk"] == 8
    for a in fleet.agents:
        assert a.loader.params.locality_chunk == 8
    # the swap commits when each stream drains its pre-pulled batches;
    # afterwards every host's schedule pins the SAME latch epoch
    for s in fleet.streams:
        while s.swaps == 0:
            next(s)
    latches = {tuple(a.loader.sampler._locality_schedule[-1])
               for a in fleet.agents}
    assert len(latches) == 1                        # one common (epoch, 8)
    assert latches.pop()[1] == 8


def test_trainer_wires_adaptive_locality_by_mode():
    """TrainerConfig.adaptive_locality: single-host controllers apply
    locally; fleet-mode controllers route proposals to the agent's
    coordinator (notify_drift) and never touch params themselves."""
    from repro.train.trainer import Trainer, TrainerConfig
    ds = synthetic_image_dataset(32, 8, seed=0)
    dl = DataLoader(ds, 8, params=LoaderParams(locality_chunk=16),
                    shuffle=True, seed=0)
    tr = Trainer.__new__(Trainer)
    tr.loader, tr.cfg, tr.agent = dl, TrainerConfig(), None
    ctl = tr._make_locality_controller()
    assert ctl.on_propose is None and ctl.loader is dl

    class FakeAgent:
        def __init__(self):
            self.proposals = []

        def notify_locality(self, chunk):
            self.proposals.append(chunk)

    tr.agent = FakeAgent()
    ctl = tr._make_locality_controller()
    ctl.observe({"coalesced_requests": 10, "reads": 160, "cache_hits": 0})
    for _ in range(2):
        ctl.observe({"coalesced_requests": ctl._last[0] + 10,
                     "reads": 160, "cache_hits": 0})
    assert tr.agent.proposals == [0]
    assert dl.params.locality_chunk == 16       # untouched locally


def test_coordinator_drops_locality_request_without_axis(fleet_factory):
    """An adaptive proposal on a fleet with no locality axis must NOT
    force a re-consensus — the search could never touch the knob, so the
    repeated proposals would burn goodput forever."""
    fleet = fleet_factory()                     # locality_chunks unset
    fleet.agents[0].notify_locality(4)
    assert fleet.coord.poll() == []             # nothing forced
    # with the axis configured the same signal IS honoured
    from repro.tuning import FleetConfig
    from conftest import make_table_evaluator
    fleet2 = fleet_factory(
        config=FleetConfig(heartbeat_timeout_s=5.0, warmup_steps=2,
                           cooldown_steps=4, num_cpu_cores=4, num_devices=1,
                           max_prefetch=2, retune_budget_batches=2,
                           locality_chunks=(0, 8)))
    for a in fleet2.agents:
        a.evaluator = make_table_evaluator(lambda i, j, c: 1.0,
                                           locality=True)
    fleet2.agents[0].notify_locality(4)
    actions = fleet2.coord.poll()
    assert any(a["kind"] == "consensus"
               and a["reason"].startswith("locality-run-len-collapse")
               for a in actions)


def test_join_syncs_fleet_locality_to_newcomer(fleet_factory):
    """Locality is runtime-mutable, so a joiner built with a stale chunk
    must inherit the fleet's (epoch -> chunk) schedule at join — or it
    would slice different permutations than its peers."""
    from repro.data import DataLoader
    from repro.tuning import HostAgent
    from conftest import make_index_dataset, make_table_evaluator
    fleet = fleet_factory(480, 12)
    # fleet-wide chunk applied earlier (simulate: set schedule directly)
    for a in fleet.agents:
        a.loader.params = a.loader.params.replace(locality_chunk=8)
        a.loader.sampler.load_locality([[0, 0], [1, 8]])
    for _ in range(2):
        for s in fleet.streams:
            next(s)
    dl_new = DataLoader(make_index_dataset(480), 12, shuffle=True, seed=5)
    newcomer = HostAgent("host3", dl_new,
                         evaluator=make_table_evaluator(lambda i, j: 1.0))
    fleet.coord.join(newcomer)
    assert dl_new.params.locality_chunk == 8
    assert dl_new.sampler.locality_state() == \
        fleet.agents[0].loader.sampler.locality_state()


def test_adaptive_controller_never_applies_locally_on_sharded_loader():
    """Library-level guard: a sharded loader with no coordinator route
    must not resize locality locally (permutation divergence)."""
    from repro.tuning import (AdaptiveLocalityConfig,
                              AdaptiveLocalityController)
    ds = synthetic_image_dataset(64, 8, seed=0)
    dl = DataLoader(ds, 16, params=LoaderParams(locality_chunk=16),
                    shuffle=True, seed=0, host_index=0, host_count=2)
    ctl = AdaptiveLocalityController(
        dl, AdaptiveLocalityConfig(patience=1, min_requests=4,
                                   cooldown_steps=0))
    ctl.observe({"coalesced_requests": 10, "reads": 160, "cache_hits": 0})
    assert ctl.observe({"coalesced_requests": 20, "reads": 180,
                        "cache_hits": 0}) is None
    assert ctl.proposals == 0
    assert dl.params.locality_chunk == 16
    # and the trainer refuses to build one at all in that topology
    from repro.train.trainer import Trainer, TrainerConfig
    tr = Trainer.__new__(Trainer)
    tr.loader, tr.cfg, tr.agent = dl, TrainerConfig(), None
    assert tr._make_locality_controller() is None


def test_fleet_locality_keeps_chunk_when_flat(fleet_factory):
    from repro.tuning import FleetConfig
    from conftest import make_table_evaluator

    fleet = fleet_factory(
        config=FleetConfig(heartbeat_timeout_s=5.0, warmup_steps=2,
                           cooldown_steps=4, num_cpu_cores=4, num_devices=1,
                           max_prefetch=2, retune_budget_batches=2,
                           locality_chunks=(0, 8)))
    for a in fleet.agents:
        a.evaluator = make_table_evaluator(lambda i, j, c: 1.0,
                                           locality=True)
    fleet.coord.request_consensus(reason="forced")
    actions = fleet.coord.poll()
    consensus = next(a for a in actions if a["kind"] == "consensus")
    assert consensus["locality_chunk"] is None
    assert not consensus["applied"]
    for a in fleet.agents:
        assert a.loader.params.locality_chunk == 0


# --------------------------------------------------------------------------
# counters: TransferStats + the monitor report
# --------------------------------------------------------------------------
def test_transfer_stats_surface_locality_counters():
    ds = _cold_dataset(128, latency_s=1e-5)
    dl = DataLoader(ds, 16, params=LoaderParams(num_workers=0),
                    shuffle=True, seed=0)
    random_stats = dl.measure_transfer_time(4, epoch=0, to_device=False,
                                            locality_chunk=0)
    chunked_stats = dl.measure_transfer_time(4, epoch=1, to_device=False,
                                             locality_chunk=16)
    assert random_stats.coalesced_requests > 0
    assert chunked_stats.coalesced_run_len > 4 * random_stats.coalesced_run_len
    assert chunked_stats.coalesced_requests < random_stats.coalesced_requests


def test_loader_io_counters_and_host_report():
    from repro.tuning.fleet import HostAgent
    ds = _cold_dataset(64, latency_s=1e-5)
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=0,
                                               locality_chunk=8),
                    shuffle=True, seed=0)
    dl.measure_transfer_time(4, epoch=0, to_device=False)
    agent = HostAgent("h0", dl)
    agent.observe(data_s=0.01, step_s=0.02)
    rep = agent.report()
    assert rep.io is not None
    assert rep.io["coalesced_requests"] > 0
    assert rep.io["coalesced_run_len"] > 1.0
    # a plain (uncounted) storage reports no io block
    ds2 = synthetic_image_dataset(32, 8, seed=0)
    dl2 = DataLoader(ds2, 8, params=LoaderParams(), shuffle=True, seed=0)
    assert HostAgent("h1", dl2).report().io is None


# --------------------------------------------------------------------------
# staging pool
# --------------------------------------------------------------------------
def test_staging_pool_acquire_release_retire_resize():
    pool = StagingPool(2)
    batch = {"x": np.zeros((4, 3), np.float32)}
    a = pool.acquire(batch)
    assert a["x"].shape == (4, 3) and pool.misses == 1
    pool.release(a)
    b = pool.acquire(batch)
    assert b is a and pool.hits == 1                # ring reuse
    pool.retire(b)
    assert pool.retired == 1
    c = pool.acquire(batch)
    assert c is not b
    # shape change drops the stale ring and re-establishes the spec
    pool.release(c)
    d = pool.acquire({"x": np.zeros((8, 3), np.float32)})
    assert d["x"].shape == (8, 3) and pool._free == type(pool._free)()
    pool.release(d)
    pool.resize(0)                                  # clamped to 1
    assert pool.capacity == 1


def test_staged_transfer_private_and_ordered():
    """With the staging pool on, zero-copy device batches must be immune
    to slab recycling (the guarantee _ensure_private used to provide)."""
    ds = synthetic_image_dataset(96, 8, seed=0)
    dl = DataLoader(ds, 16, params=LoaderParams(
        num_workers=2, zero_copy=True, staging_buffers=2),
        shuffle=False, seed=0)
    stream = dl.stream(to_device=True)
    got = [next(stream) for _ in range(6)]          # one epoch
    for b, dev in enumerate(got):                   # values still intact?
        ref = ds.get_batch(dl.sampler.local_indices(0, b), fast=False)
        np.testing.assert_array_equal(np.asarray(dev["image"]),
                                      ref["image"])
    assert stream._prefetcher.staging_hit_rate is not None
    stream.close()


def test_staging_disabled_falls_back_to_ensure_private():
    ds = synthetic_image_dataset(48, 8, seed=0)
    dl = DataLoader(ds, 16, params=LoaderParams(
        num_workers=1, zero_copy=True, staging_buffers=0),
        shuffle=False, seed=0)
    stream = dl.stream(to_device=True)
    got = [next(stream) for _ in range(3)]
    assert stream._prefetcher.staging_hit_rate is None
    for b, dev in enumerate(got):
        ref = ds.get_batch(dl.sampler.local_indices(0, b), fast=False)
        np.testing.assert_array_equal(np.asarray(dev["image"]),
                                      ref["image"])
    stream.close()


def test_set_staging_hot_swaps_with_depth():
    ds = synthetic_image_dataset(64, 8, seed=0)
    dl = DataLoader(ds, 8, params=LoaderParams(num_workers=1,
                                               zero_copy=True),
                    shuffle=False, seed=0)
    stream = dl.stream(to_device=True)
    next(stream)
    dl.apply_params(dl.params.replace(device_prefetch=3, staging_buffers=4))
    for _ in range(6):
        next(stream)
    assert stream.swaps == 1
    assert stream._prefetcher.depth == 3
    assert stream._prefetcher._staging.capacity == 4
    stream.close()


# --------------------------------------------------------------------------
# FileStorage fork hygiene
# --------------------------------------------------------------------------
@pytest.mark.skipif(not hasattr(os, "fork"), reason="fork-only")
def test_filestorage_fork_drops_inherited_mmaps(tmp_path):
    items = [np.arange(i, i + 6, dtype=np.int32) for i in range(4)]
    fs = FileStorage.create(str(tmp_path / "fs"), items)
    fs._mmap(0)
    fs._mmap(1)
    assert len(fs._mmaps) == 2
    r, w = mp.Pipe(duplex=False)
    pid = os.fork()
    if pid == 0:                                    # child
        ok = False
        try:
            inherited = len(fs._mmaps)              # should be reset to 0
            data = fs.read_batch([0, 1, 2])         # lazily reopens
            ok = (inherited == 0
                  and np.array_equal(data[2], items[2]))
        finally:
            w.send(ok)
            os._exit(0)
    assert r.poll(10)
    assert r.recv() is True
    os.waitpid(pid, 0)
    # parent cache untouched
    assert len(fs._mmaps) == 2
