"""Dual-lane slow-sample isolation (DESIGN.md §9).

Covers the whole chain: the per-item cost tracker (EM attribution,
exoneration, checkpointing), the dual-lane worker pools (ordered delivery
and exact coverage with stragglers planted), the heavy-tailed storage
mode's determinism, the simulator's lane pricing, the retune-time lane
sweep, the DPT cache's lane axis, the tail-ratio retune trigger, and the
serving frontend's slow group lane.
"""
import math
import pickle
import time

import numpy as np
import pytest

from conftest import flat_indices, make_cold_dataset, make_index_dataset

from repro.data import DataLoader, LoaderParams
from repro.data.costs import (KeyedCostTracker, SampleCostTracker,
                              percentile)

SLOW_EVERY = 16                   # planted straggler population: idx % 16


def _sleepy_transform(a):
    """Picklable index transform: every SLOW_EVERY-th item is a straggler
    (works in thread AND forked process workers)."""
    if int(a[0]) % SLOW_EVERY == 0:
        time.sleep(3e-3)
    return {"x": a}


# --------------------------------------------------------------------------
# SampleCostTracker: EM attribution over batch-aggregate timings
# --------------------------------------------------------------------------
def _feed_epochs(tracker, n, batch, *, epochs, slow_idx, base=1e-3,
                 extra=2e-2, seed=0):
    """Simulate recorded batches: every item costs ``base``; members of
    ``slow_idx`` add ``extra``.  Shuffled like a real epoch."""
    rng = np.random.default_rng(seed)
    for e in range(epochs):
        perm = rng.permutation(n)
        for b in range(n // batch):
            idx = perm[b * batch:(b + 1) * batch]
            total = base * batch + extra * np.isin(idx, slow_idx).sum()
            tracker.record(idx, float(total))


def test_tracker_learns_planted_straggler():
    n, batch = 64, 4
    t = SampleCostTracker(n)
    _feed_epochs(t, n, batch, epochs=4, slow_idx=[7])
    est = t.predict(np.arange(n))
    # the straggler's estimate separates cleanly from the fast population
    assert est[7] > 4.0 * np.median(est)
    assert t.is_slow([7, 1, 2, 3])
    assert not t.is_slow([1, 2, 3, 4])
    assert t.tail_ratio() > 4.0


def test_tracker_cold_never_routes():
    t = SampleCostTracker(64, min_records=8)
    for _ in range(7):                 # one short of min_records
        t.record([0, 1, 2, 3], 10.0)
    assert not t.is_slow([0, 1, 2, 3])


def test_tracker_exonerates_falsely_blamed_items():
    """An item that shared its batches with a straggler (shared blame
    while both were unseen) must be cleared by later fast sightings."""
    t = SampleCostTracker(64)
    # fast baseline: the median item cost settles at ~1ms
    for _ in range(3):
        for s in range(16, 64, 4):
            t.record([s, s + 1, s + 2, s + 3], 4e-3)
    # cold blame: 9 only ever rides in the straggler's batch, so the
    # outlier attribution has no evidence to separate them yet
    for _ in range(3):
        t.record([7, 9, 1, 2], 4e-3 + 2e-2)
    assert t.is_slow([9, 16, 17, 18])      # falsely suspected, for now
    # then 9 shows up in evidently-fast company while 7 stays slow
    for _ in range(4):
        t.record([9, 20, 21, 22], 4e-3)
        t.record([7, 24, 25, 26], 4e-3 + 2e-2)
    assert t.is_slow([7, 16, 17, 18])
    assert not t.is_slow([9, 20, 21, 22])


def test_tracker_state_roundtrip_and_pickle():
    n = 64
    a = SampleCostTracker(n)
    _feed_epochs(a, n, 4, epochs=3, slow_idx=[5, 21])
    b = SampleCostTracker(n)
    b.load_state_dict(a.state_dict())
    np.testing.assert_allclose(b.predict(np.arange(n)),
                               a.predict(np.arange(n)))
    assert b.records == a.records and b.is_slow([5, 1, 2, 3])
    # workers receive the tracker by reference in threads and by pickle in
    # forked pools' parents — it must survive the trip with its table
    c = pickle.loads(pickle.dumps(a))
    np.testing.assert_allclose(c.predict(np.arange(n)),
                               a.predict(np.arange(n)))
    assert c.is_slow([5, 1, 2, 3])


def test_tracker_bucket_fallback_bounds_table():
    t = SampleCostTracker(1 << 20, max_slots=1 << 10)
    assert t.bucket >= (1 << 10)
    assert t._ewma.size <= (1 << 10)
    # slots alias by design; recording and prediction still work
    _feed_epochs(t, 4096, 4, epochs=2, slow_idx=[])
    assert t.records > 0 and t.mean() > 0


def test_keyed_tracker_slow_key_and_roundtrip():
    t = KeyedCostTracker(min_records=4)
    for _ in range(4):
        t.record((16, 4), 0.002)
        t.record((512, 64), 0.050)
    assert t.is_slow((512, 64))
    assert not t.is_slow((16, 4))
    assert not t.is_slow((999, 9))     # unknown key is never slow
    b = KeyedCostTracker()
    b.load_state_dict(t.state_dict())
    assert b.is_slow((512, 64)) and b.predict((16, 4)) == t.predict((16, 4))


def test_percentile_helper():
    assert percentile([], 0.99) == 0.0
    assert percentile([1.0, 2.0, 3.0], 0.5) == 2.0


# --------------------------------------------------------------------------
# LoaderParams validation: misconfiguration fails loudly
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [
    {"slow_lane_workers": -1},
    {"slow_lane_lookahead": -1},
    {"slow_lane_threshold": 1.0},
    {"slow_lane_threshold": 0.5},
    {"use_processes": True, "ordered": False},
])
def test_loader_params_rejects_bad_lane_config(kw):
    with pytest.raises(ValueError):
        LoaderParams(**kw)


def test_arena_capacity_covers_lane_lookahead():
    base = LoaderParams(num_workers=2, zero_copy=True)
    lane = base.replace(slow_lane_workers=2, slow_lane_lookahead=8)
    # the slow lane's early-start span needs its own slots
    assert lane.arena_capacity() >= base.arena_capacity() + 2 + 8


# --------------------------------------------------------------------------
# dual-lane pools: ordered delivery + exact coverage with stragglers live
# --------------------------------------------------------------------------
def _lane_params(**kw):
    base = dict(num_workers=2, prefetch_factor=2, ordered=True,
                slow_lane_workers=2, slow_lane_lookahead=8,
                slow_lane_threshold=4.0)
    base.update(kw)
    return LoaderParams(**base)


def test_dual_lane_thread_pool_ordered_exact_coverage():
    """Three epochs through the real thread pool with the slow lane on:
    every epoch is delivered in exact sampler order (the lanes merge at
    the reorder buffer) and covers the dataset exactly once — and after
    the warm-up epoch the tracker routes batches to the slow lane."""
    n, gb = 96, 8
    ds = make_index_dataset(n, transform=_sleepy_transform)
    dl = DataLoader(ds, gb, params=_lane_params(), shuffle=True, seed=0)
    for epoch in range(3):
        batches = list(dl.host_batches(epoch=epoch, num_batches=n // gb))
        assert flat_indices(batches) == list(range(n))
        want = [dl.sampler.local_indices(epoch, b).tolist()
                for b in range(n // gb)]
        got = [np.asarray(b["x"])[:, 0].tolist() for b in batches]
        assert got == want, f"epoch {epoch} delivered out of order"
    assert dl.cost_tracker.records > 0
    assert dl.cost_tracker.slow_batches > 0, \
        "warm tracker never routed a straggler batch to the slow lane"
    io = dl.io_counters()
    assert io["sample_cost_tail_ratio"] > 1.0
    assert io["sample_cost_p99_s"] >= io["sample_cost_mean_s"]


def test_dual_lane_process_pool_ordered_exact_coverage():
    """The process pool's consumer-driven lane pump: same order and
    coverage guarantees (delivery is inherently ordered there)."""
    n, gb = 48, 8
    ds = make_index_dataset(n, transform=_sleepy_transform)
    dl = DataLoader(ds, gb,
                    params=_lane_params(use_processes=True, fast_path=False),
                    shuffle=True, seed=1)
    for epoch in range(2):
        batches = list(dl.host_batches(epoch=epoch, num_batches=n // gb))
        assert flat_indices(batches) == list(range(n))
        want = [dl.sampler.local_indices(epoch, b).tolist()
                for b in range(n // gb)]
        got = [np.asarray(b["x"])[:, 0].tolist() for b in batches]
        assert got == want
    assert dl.cost_tracker.records > 0


def test_lane_off_without_order_is_inert():
    """ordered=False (threads): the lane silently disables — there is no
    head-of-line pathology to fix — and delivery still covers exactly."""
    n, gb = 48, 8
    ds = make_index_dataset(n, transform=_sleepy_transform)
    dl = DataLoader(ds, gb, params=_lane_params(ordered=False),
                    shuffle=True, seed=0)
    batches = list(dl.host_batches(epoch=0, num_batches=n // gb))
    assert flat_indices(batches) == list(range(n))
    assert dl.cost_tracker.slow_batches == 0


def test_measure_transfer_time_lane_override_and_counters():
    """The slow-lane axis's measurement-only override: a trial at a
    candidate width must not touch the live params, and TransferStats
    carries the tail-cost counters."""
    n, gb = 48, 8
    ds = make_index_dataset(n, transform=_sleepy_transform)
    dl = DataLoader(ds, gb, params=LoaderParams(num_workers=2),
                    shuffle=True, seed=0)
    st = dl.measure_transfer_time(n // gb, epoch=0, to_device=False,
                                  slow_lane_workers=2)
    assert dl.params.slow_lane_workers == 0      # live params untouched
    assert st.sample_cost_mean_s > 0
    assert st.sample_cost_p99_s >= st.sample_cost_mean_s


def test_cost_tracker_rides_loader_checkpoint():
    n, gb = 64, 8
    ds = make_index_dataset(n, transform=_sleepy_transform)
    dl = DataLoader(ds, gb, params=_lane_params(), shuffle=True, seed=0)
    for e in range(2):
        list(dl.host_batches(epoch=e, num_batches=n // gb))
    saved = dl.state_dict()
    dl2 = DataLoader(make_index_dataset(n, transform=_sleepy_transform),
                     gb, params=_lane_params(), shuffle=True, seed=0)
    dl2.load_state_dict(saved)
    np.testing.assert_allclose(dl2.cost_tracker.predict(np.arange(n)),
                               dl.cost_tracker.predict(np.arange(n)))
    assert dl2.cost_tracker.records == dl.cost_tracker.records


# --------------------------------------------------------------------------
# heavy-tailed LatencyStorage: deterministic planted stragglers
# --------------------------------------------------------------------------
def test_latency_storage_tail_is_deterministic():
    from repro.data import ArrayStorage, LatencyStorage
    items = [np.zeros(4, np.float32) for _ in range(256)]

    def mk(seed):
        return LatencyStorage(ArrayStorage(items), latency_s=1e-5,
                              tail_fraction=0.05, tail_mult=20.0,
                              tail_seed=seed)

    a, b = mk(3), mk(3)
    mults = [a.tail_multiplier(i) for i in range(256)]
    assert mults == [b.tail_multiplier(i) for i in range(256)]
    assert mults == [a.tail_multiplier(i) for i in range(256)]  # stable
    tails = [i for i in range(256) if a.is_tail(i)]
    assert 1 <= len(tails) <= 40                  # ~5% of 256, wide margin
    assert all(a.tail_multiplier(i) == 20.0 for i in tails)
    # a different seed plants a different straggler set
    c = mk(4)
    assert tails != [i for i in range(256) if c.is_tail(i)]
    # the extra sleep charged is (mult - 1) base latencies per tail item
    assert a._tail_extra_s([tails[0]]) == pytest.approx(19.0 * 1e-5)
    assert a._tail_extra_s([(tails[0] + 1) % 256]) == 0.0


def test_latency_storage_lognormal_mode():
    from repro.data import ArrayStorage, LatencyStorage
    items = [np.zeros(4, np.float32) for _ in range(512)]
    st = LatencyStorage(ArrayStorage(items), latency_s=1e-5,
                        tail_fraction=1.0, tail_mult=20.0,
                        tail_mode="lognormal")
    mults = np.array([st.tail_multiplier(i) for i in range(512)])
    assert np.median(mults) == pytest.approx(1.0, rel=0.3)
    assert mults.max() > 4.0                     # a real tail exists
    with pytest.raises(ValueError):
        LatencyStorage(ArrayStorage(items), tail_mode="pareto")


def test_cold_dataset_tail_passthrough():
    ds = make_cold_dataset(32, latency_s=1e-5, tail_fraction=0.1,
                           tail_mult=10.0, tail_seed=2)
    st = ds.storage
    assert st.tail_fraction == 0.1 and st.tail_mult == 10.0
    assert any(st.is_tail(i) for i in range(32))


# --------------------------------------------------------------------------
# simulator: the fifth axis prices out of heavy-tailed profiles only
# --------------------------------------------------------------------------
def _decode_heavy_profile():
    import dataclasses
    from repro.data.storage import cifar10_profile
    return dataclasses.replace(cifar10_profile(), decode_cpu_s_fixed=1e-3,
                               vectorized_decode_fixed_s=None)


def _sim(profile):
    from repro.core.simulator import LoaderSimulator, MachineProfile
    return LoaderSimulator(profile, MachineProfile(
        physical_cores=8, logical_cores=8, reserved_cores=0, num_devices=2))


def test_simulator_neutral_profile_lane_free_is_identity():
    sim = _sim(_decode_heavy_profile())
    a = sim.simulate(batch_size=4, num_batches=64, nworker=2, nprefetch=1)
    b = sim.simulate(batch_size=4, num_batches=64, nworker=2, nprefetch=1,
                     slow_lane_workers=0)
    assert a.seconds == b.seconds and a.peak_bytes == b.peak_bytes


def test_simulator_prices_lane_on_heavy_tail():
    heavy = _decode_heavy_profile().with_heavy_tail(fraction=0.03,
                                                    mult=100.0)
    sim = _sim(heavy)
    t0 = sim.simulate(batch_size=4, num_batches=64, nworker=2,
                      nprefetch=1).seconds
    t1 = sim.simulate(batch_size=4, num_batches=64, nworker=2, nprefetch=1,
                      slow_lane_workers=1).seconds
    assert t1 < t0, "a slow lane must pay off on the straggler profile"
    # on a uniform profile the lane is pure overhead
    uni = _sim(_decode_heavy_profile())
    u0 = uni.simulate(batch_size=4, num_batches=64, nworker=2,
                      nprefetch=1).seconds
    u1 = uni.simulate(batch_size=4, num_batches=64, nworker=2, nprefetch=1,
                      slow_lane_workers=1).seconds
    assert u1 >= u0


def test_dpt_grid_resolves_lane_axis():
    """The full grid (workers x prefetch x lanes) picks a nonzero lane
    width on the heavy-tailed decode profile and zero on the uniform one
    — the knob only spends workers where stragglers exist."""
    from repro.core.dpt import DPTConfig
    from repro.core.evaluators import SimulatorEvaluator
    from repro.tuning import tune

    def pick(profile):
        ev = SimulatorEvaluator(_sim(profile), batch_size=4)
        cfg = DPTConfig(num_cpu_cores=8, num_devices=2, min_prefetch=1,
                        max_prefetch=2, num_batches=64,
                        slow_lanes=(0, 1, 2, 3))
        return tune(evaluator=ev, strategy="grid", config=cfg,
                    measure_default=False)

    heavy = pick(_decode_heavy_profile().with_heavy_tail(fraction=0.03,
                                                         mult=100.0))
    assert heavy.slow_lane_workers > 0
    assert any(t.slow_lane_workers for t in heavy.trials)
    uniform = pick(_decode_heavy_profile())
    assert uniform.slow_lane_workers == 0


def test_dpt_grid_without_lane_axis_never_passes_kwarg():
    """slow_lanes=None keeps the search lane-blind: evaluators that never
    heard of the axis must keep working (the None-contract)."""
    from conftest import make_table_evaluator
    from repro.core.dpt import DPTConfig
    from repro.tuning import tune
    ev = make_table_evaluator(lambda i, j: 1.0 / i + 0.1 * j)
    r = tune(evaluator=ev, strategy="grid",
             config=DPTConfig(num_cpu_cores=4, num_devices=2,
                              max_prefetch=2),
             measure_default=False)
    assert r.slow_lane_workers == 0
    assert all(t.slow_lane_workers == 0 for t in r.trials)


# --------------------------------------------------------------------------
# retune-time lane sweep + win test
# --------------------------------------------------------------------------
def _lane_table_evaluator(fn):
    from repro.data.loader import TransferStats

    def ev(i, j, *, num_batches=16, epoch=0, slow_lane_workers=None):
        ev.calls += 1
        return TransferStats(fn(i, j, slow_lane_workers or 0),
                             num_batches, 0)
    ev.calls = 0
    return ev


def test_sweep_slow_lanes_and_win():
    from repro.tuning import slow_lane_win, sweep_slow_lanes
    ev = _lane_table_evaluator(lambda i, j, k: 1.0 / (1 + k))
    trials = sweep_slow_lanes(ev, nworker=2, nprefetch=1, lanes=(0, 2, 4),
                              current_lanes=0, num_batches=8)
    assert set(trials) == {0, 2, 4}
    assert all(t.slow_lane_workers == k for k, t in trials.items())
    assert slow_lane_win(trials, 0) == 4


def test_slow_lane_win_defends_current():
    from repro.tuning import slow_lane_win
    from repro.core.dpt import Trial
    # a 2% improvement does not clear the 5% threshold
    trials = {0: Trial(2, 1, 1.00, slow_lane_workers=0),
              2: Trial(2, 1, 0.98, slow_lane_workers=2)}
    assert slow_lane_win(trials, 0) is None
    # the current width being the argmin is never a "win"
    trials[2] = Trial(2, 1, 1.50, slow_lane_workers=2)
    assert slow_lane_win(trials, 0) is None
    # an overflowed candidate never wins
    trials = {0: Trial(2, 1, 1.0, slow_lane_workers=0),
              2: Trial(2, 1, math.inf, overflowed=True,
                       slow_lane_workers=2)}
    assert slow_lane_win(trials, 0) is None


def test_sweep_slow_lanes_handles_overflow():
    from repro.core.monitor import MemoryOverflow
    from repro.tuning import sweep_slow_lanes

    def ev(i, j, *, num_batches=16, epoch=0, slow_lane_workers=None):
        if (slow_lane_workers or 0) > 2:
            raise MemoryOverflow("lane widened past the budget")
        from repro.data.loader import TransferStats
        return TransferStats(1.0, num_batches, 0)

    trials = sweep_slow_lanes(ev, nworker=2, nprefetch=1, lanes=(0, 2, 4),
                              current_lanes=0, num_batches=8)
    assert trials[4].overflowed and math.isinf(trials[4].seconds)
    assert not trials[2].overflowed


# --------------------------------------------------------------------------
# DPT cache: the lane axis persists with staleness semantics
# --------------------------------------------------------------------------
def _result(lane, *, searched):
    from repro.core.dpt import DPTResult, Trial
    trials = [Trial(2, 1, 1.0, slow_lane_workers=k)
              for k in ((0, lane) if searched else (0,))]
    return DPTResult(2, 1, 1.0, trials, slow_lane_workers=lane)


def test_dpt_cache_lane_axis_roundtrip(tmp_path):
    from repro.core.cache import DPTCache
    path = str(tmp_path / "dpt.json")
    cache = DPTCache(path)
    cache.put("m", "d", 32, _result(2, searched=True))
    got = cache.get_params("m", "d", 32, with_slow_lane=True,
                           require_slow_lane=True)
    assert got is not None and got[-1] == 2
    # persists across a reload
    assert DPTCache(path).get_params("m", "d", 32,
                                     with_slow_lane=True)[-1] == 2


def test_dpt_cache_lane_blind_entry_is_stale():
    from repro.core.cache import DPTCache
    cache = DPTCache()
    cache.put("m", "d", 32, _result(0, searched=False))
    assert cache.get_params("m", "d", 32, require_slow_lane=True) is None
    assert cache.get_params("m", "d", 32) is not None   # still fine 3-axis


def test_dpt_cache_lane_blind_refinement_never_clobbers():
    from repro.core.cache import DPTCache
    cache = DPTCache()
    cache.put("m", "d", 32, _result(2, searched=True))
    # an online 2-axis retune refines (workers, prefetch) lane-blind;
    # the searched lane width must survive
    cache.put("m", "d", 32, _result(0, searched=False))
    got = cache.get_params("m", "d", 32, with_slow_lane=True,
                           require_slow_lane=True)
    assert got is not None and got[-1] == 2


# --------------------------------------------------------------------------
# online retune trigger: the cost tail is drift
# --------------------------------------------------------------------------
def test_tail_ratio_trigger_arms_only_with_lanes():
    from repro.tuning.online import (GoodputMonitor, OnlineTunerConfig,
                                     RetunePolicy)
    mon = GoodputMonitor()
    mon.note_tail(50.0)
    armed = RetunePolicy(OnlineTunerConfig(slow_lanes=(0, 2),
                                           tail_ratio_trigger=10.0))
    assert armed.drifted(mon)
    below = GoodputMonitor()
    below.note_tail(5.0)
    assert not armed.drifted(below)
    # no lane axis -> the tail signal cannot trigger a search that could
    # never act on it
    disarmed = RetunePolicy(OnlineTunerConfig(tail_ratio_trigger=10.0))
    assert not disarmed.drifted(mon)
    off = RetunePolicy(OnlineTunerConfig(slow_lanes=(0, 2)))
    assert not off.drifted(mon)


def test_online_tuner_observe_feeds_tail_signal():
    """The OnlineTuner pulls io_counters' tail ratio into its monitor once
    per window — the plumbing between the loader's tracker and the
    policy."""
    from repro.tuning.online import OnlineTuner, OnlineTunerConfig
    n, gb = 96, 8
    ds = make_index_dataset(n, transform=_sleepy_transform)
    dl = DataLoader(ds, gb, params=_lane_params(), shuffle=True, seed=0)
    for e in range(2):                 # warm the tracker
        list(dl.host_batches(epoch=e, num_batches=n // gb))
    cfg = OnlineTunerConfig(window=4, warmup_steps=10**6,  # never searches
                            slow_lanes=(0, 2), tail_ratio_trigger=1.5)
    tuner = OnlineTuner(dl, evaluator=None, config=cfg)
    for _ in range(cfg.window):
        tuner.observe(data_s=0.0, step_s=0.01)
    assert tuner.monitor.tail_ratio > 1.5


# --------------------------------------------------------------------------
# serving: expensive request groups take the slow lane
# --------------------------------------------------------------------------
class _FakeEngine:
    """Duck-typed stand-in for ServeEngine: expensive when max_new is
    large, instant otherwise."""
    max_batch = 4

    def generate(self, prompts, max_new):
        time.sleep(0.04 if max_new >= 64 else 0.001)

        class R:
            tokens = np.zeros((len(prompts), max_new), np.int32)
        return R()


def test_frontend_slow_lane_isolates_expensive_groups():
    from repro.serve.engine import BatchingFrontend
    fe = BatchingFrontend(_FakeEngine(), max_wait_s=0.002, slow_lane=True,
                          slow_threshold=4.0)
    try:
        rng = np.random.default_rng(0)

        def burst(k, max_new):
            return [fe.submit(rng.integers(0, 100, (16,)).astype(np.int32),
                              max_new) for _ in range(k)]

        # warm the keyed tracker with both shapes (the tracker records
        # once per served GROUP, so several rounds are needed)
        for _ in range(4):
            for r in burst(2, 4) + burst(2, 64):
                r.result.get(timeout=30)
        assert fe.cost_tracker.is_slow((16, 64))
        # now a mixed burst: the expensive group must route to the slow
        # thread and everything still completes
        reqs = burst(6, 64) + burst(6, 4)
        outs = [r.result.get(timeout=30) for r in reqs]
        assert len(outs) == 12
        assert fe.slow_groups > 0
        assert fe.assembly_wait_p99() >= 0.0
        assert fe.assembly_wait_p99(slow=True) > 0.0
    finally:
        fe.shutdown()
