"""Roofline-term derivation from a compiled dry-run artifact.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(assignment constants).

Inputs are per-device (the analyzed module is the SPMD partition):

    compute_s    = flops_per_device / PEAK_FLOPS
    memory_s     = traffic_bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified: a
5-iteration scanned matmul reports 1 iteration of flops), which undercounts
scan-over-layers models by ~num_layers.  So flops/traffic/collective bytes
are re-derived from the optimized HLO with loop trip-count weighting
(roofline/hlo_parser.py); cost_analysis values are kept in the artifact as
the body-once lower bound.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.roofline.hlo_parser import HloAnalysis, analyze_module

# --- hardware constants (TPU v5e per assignment) ----------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (assignment: ~50 GB/s/link)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float              # trip-weighted HLO dot flops
    traffic_bytes_per_device: float      # post-fusion HBM traffic model
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    collective_counts: Dict[str, int]
    cost_flops_body_once: float          # raw cost_analysis (lower bound)
    cost_bytes_body_once: float
    hbm_per_device: float                # resident: args+temps+outputs
    model_flops: float                   # analytic global FLOPs per step
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """No-overlap lower bound on step time: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops x chips): <1 flags remat/redundant
        compute; >1 flags padding of the analytic model (e.g. embeddings)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute_s / step_s: 1.0 = compute-bound at the hardware peak."""
        return self.compute_s / self.step_s if self.step_s else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, step_s=self.step_s,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS per step: 6*N_active*D train / 2*N_active*D
    prefill / 2*N_active per generated token for decode."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch


def build_report(*, arch: str, shape, mesh_name: str, chips: int,
                 cost: Dict[str, float], mem, hlo_text: str,
                 cfg) -> RooflineReport:
    parsed: HloAnalysis = analyze_module(hlo_text)
    cost_flops = float(cost.get("flops", 0.0))
    cost_bytes = float(cost.get("bytes accessed", 0.0))
    # dot-flops miss elementwise work; cost_analysis misses loop trips —
    # take the max as the best per-device estimate.
    flops = max(parsed.dot_flops, cost_flops)
    traffic = max(parsed.traffic_bytes, cost_bytes)
    hbm = float(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes)
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops,
        traffic_bytes_per_device=traffic,
        collective_bytes_per_device=parsed.total_collective_bytes,
        collective_breakdown=parsed.collective_bytes,
        collective_counts=parsed.collective_counts,
        cost_flops_body_once=cost_flops,
        cost_bytes_body_once=cost_bytes,
        hbm_per_device=hbm,
        model_flops=model_flops_for(cfg, shape),
        compute_s=flops / PEAK_FLOPS,
        memory_s=traffic / HBM_BW,
        collective_s=parsed.total_collective_bytes / ICI_BW,
    )
