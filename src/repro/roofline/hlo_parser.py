"""Trip-count-aware analyzer for XLA optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, but a
scan-over-layers train step executes it num_layers times — so flops/bytes
from cost_analysis undercount by ~L for deep models (verified empirically:
a 5-step scanned matmul reports exactly 1 step of flops).  This module
re-derives the roofline inputs from the optimized HLO itself:

* dot/convolution FLOPs weighted by loop trip counts
  (``backend_config={"known_trip_count":{"n":"88"}}``),
* HBM traffic model: post-fusion, each instruction is one kernel that
  reads its operands and writes its result; traffic = sum of both (the
  standard post-fusion approximation — real traffic is lower when operands
  stay in cache/registers, higher on spills),
* collective bytes by kind (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), operand bytes, trip-weighted.

All quantities are per-device: the input is the SPMD-partitioned module.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([0-9,]*)\]")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[a-z]\w*\[[0-9,]*\]\S*)"
    r"\s+([a-z][\w\-]*)\((.*)$")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[\\\":{]+n[\\\":]+(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str                      # everything after the opening call paren

    @property
    def result_bytes(self) -> int:
        return shape_bytes(self.type_str)

    def call_args(self) -> str:
        """Text inside the call parens (operand list)."""
        depth = 1
        out = []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            out.append(ch)
        return "".join(out)

    def operand_names(self) -> List[str]:
        return _OPERAND_RE.findall(self.call_args())

    def attrs(self) -> str:
        args = self.call_args()
        return self.rest[len(args):]


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        h = _HEADER_RE.match(line)
        if h:
            current = Computation(h.group(2), bool(h.group(1)), [])
            comps[current.name] = current
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            current.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                        m.group(4)))
    return comps


@dataclasses.dataclass
class HloAnalysis:
    dot_flops: float
    traffic_bytes: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, int]
    unknown_trip_loops: int

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_module(text: str, on_instr=None) -> HloAnalysis:
    """on_instr: optional callback (comp, instr, mult, traffic) for
    debugging/top-contributor reports."""
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    acc = HloAnalysis(0.0, 0.0, {k: 0.0 for k in COLLECTIVE_OPS},
                      {k: 0 for k in COLLECTIVE_OPS}, 0)
    if entry is None:
        return acc

    shape_of: Dict[Tuple[str, str], str] = {}
    for c in comps.values():
        for ins in c.instrs:
            shape_of[(c.name, ins.name)] = ins.type_str

    def operand_bytes(comp: Computation, ins: Instr,
                      trip_stack: Tuple[float, ...] = ()) -> int:
        """Sum operand bytes; an operand whose leading dim equals an
        enclosing loop's trip count is a stacked per-iteration buffer
        (scan-over-layers weights / saved activations) that the iteration
        only slices — count 1/leading of it."""
        total = 0
        for op_name in ins.operand_names():
            t = shape_of.get((comp.name, op_name))
            if t is None:
                continue
            b = shape_bytes(t)
            dims = _shape_dims(t)
            if dims and dims[0] > 1 and float(dims[0]) in trip_stack:
                b = b // dims[0]
            total += b
        return total

    def dot_flops_of(comp: Computation, ins: Instr) -> float:
        result_dims = _shape_dims(ins.type_str)
        n = 1
        for d in result_dims:
            n *= d
        ops = ins.operand_names()
        lhs_t = shape_of.get((comp.name, ops[0])) if ops else None
        cdims = _LHS_CONTRACT_RE.search(ins.rest)
        contract = 1
        if lhs_t and cdims:
            ldims = _shape_dims(lhs_t)
            for d in cdims.group(1).split(","):
                if d and int(d) < len(ldims):
                    contract *= ldims[int(d)]
        return 2.0 * n * contract

    def walk_fusion(comp: Computation, mult: float, depth: int = 0) -> None:
        """Dots/convs fused into a kernel still count as flops (but the
        fusion's traffic was already counted at the call site)."""
        if depth > 4:
            return
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                acc.dot_flops += mult * dot_flops_of(comp, ins)
            elif ins.op == "fusion":
                cm = _CALLS_RE.search(ins.rest)
                if cm and cm.group(1) in comps:
                    walk_fusion(comps[cm.group(1)], mult, depth + 1)

    def walk(comp: Computation, mult: float, depth: int = 0,
             trip_stack: Tuple[float, ...] = ()) -> None:
        if depth > 32:  # defensive: malformed module
            return
        for ins in comp.instrs:
            if ins.op == "while":
                attrs = ins.attrs() + ins.rest
                bm = _BODY_RE.search(attrs)
                tm = _TRIP_RE.search(attrs)
                trips = float(tm.group(1)) if tm else 1.0
                if tm is None:
                    acc.unknown_trip_loops += 1
                if bm and bm.group(1) in comps:
                    walk(comps[bm.group(1)], mult * trips, depth + 1,
                         trip_stack + (trips,))
                continue
            if ins.op in ("call", "conditional", "async-start"):
                cm = _CALLS_RE.search(ins.rest)
                if cm and cm.group(1) in comps:
                    walk(comps[cm.group(1)], mult, depth + 1, trip_stack)
                continue
            if ins.op in _SKIP_OPS:
                continue
            rb = ins.result_bytes
            rdims = _shape_dims(ins.type_str)
            if rdims and rdims[0] > 1 and float(rdims[0]) in trip_stack:
                rb //= rdims[0]   # in-place update of a stacked carry buffer
            ob = operand_bytes(comp, ins, trip_stack)
            # slice-like ops touch only the slice region, not the whole
            # operand buffer (stacked per-layer weights are dynamic-sliced
            # inside the scan loop — counting the full stack per iteration
            # would overcount by num_layers).
            if ins.op in ("dynamic-slice", "slice", "gather"):
                traffic = 2.0 * rb
            elif ins.op in ("dynamic-update-slice", "scatter"):
                ops = ins.operand_names()
                upd = shape_of.get((comp.name, ops[1])) if len(ops) > 1 else None
                ub = shape_bytes(upd) if upd else rb
                traffic = 2.0 * ub
            else:
                traffic = rb + ob
            acc.traffic_bytes += mult * traffic
            if on_instr is not None:
                on_instr(comp, ins, mult, traffic)
            if ins.op in ("dot", "convolution"):
                acc.dot_flops += mult * dot_flops_of(comp, ins)
            elif ins.op == "fusion":
                cm = _CALLS_RE.search(ins.rest)
                if cm and cm.group(1) in comps:
                    walk_fusion(comps[cm.group(1)], mult)
            if ins.op in COLLECTIVE_OPS:
                b = max(ob, rb)
                # XLA's CPU backend PROMOTES bf16 all-reduces to f32 with
                # convert round-trips around them (to_apply=..._promoted);
                # the TPU target runs them native bf16 — count wire bytes
                # at the real dtype, not the CPU-promotion artifact.
                if "promoted" in ins.rest:
                    b //= 2
                # algorithmic wire factor: a ring all-reduce moves ~2N per
                # device (reduce-scatter phase + all-gather phase); AG/RS/
                # A2A/permute move ~N.  Without this AR is undercounted 2x
                # vs the AG+RS decomposition it competes with.
                if ins.op == "all-reduce":
                    b *= 2
                acc.collective_bytes[ins.op] += mult * b
                acc.collective_counts[ins.op] += 1
    walk(entry, 1.0)
    return acc


def top_traffic(text: str, n: int = 15):
    """Top-n instructions by trip-weighted traffic (debugging aid)."""
    rows = []

    def cb(comp, ins, mult, traffic):
        rows.append((traffic, mult, comp.name, ins.op, ins.name,
                     ins.type_str[:60]))

    analyze_module(text, on_instr=cb)
    rows.sort(reverse=True)
    return rows[:n]
