"""qwen2-0.5b — dense GQA with QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig, register

QWEN2_0_5B = register(ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    attn_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="[arXiv:2407.10671; hf]",
))
