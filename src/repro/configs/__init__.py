from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    SMOKE_SHAPE,
    SMOKE_DECODE_SHAPE,
    applicable_shapes,
    get_config,
    list_configs,
    reduced,
    register,
)
