"""whisper-large-v3 — encoder-decoder audio backbone; conv frontend stubbed
(input_specs() feeds precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig, register

WHISPER_LARGE_V3 = register(ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,           # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,         # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    attn_bias=True,
    max_source_positions=1500,
    mlp_activation="gelu",
    tie_embeddings=True,
    source="[arXiv:2212.04356; unverified]",
))
