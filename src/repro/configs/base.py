"""Architecture and shape configuration system.

Every assigned architecture is a ``ModelConfig`` registered under its id and
selectable via ``--arch <id>`` in the launchers.  ``ShapeConfig`` carries the
assigned (seq_len, global_batch, kind) cells.  ``reduced()`` derives the tiny
smoke-test variant of any config (same family / code paths, laptop-size).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert FFN width (0 -> use d_ff)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # --- SSM (mamba2 SSD) ---
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_num_groups: int = 1
    # --- attention details ---
    qk_norm: bool = False
    attn_bias: bool = False          # qwen2-style QKV bias
    sliding_window: int = 0          # 0 = full attention
    global_attn_layers: Tuple[int, ...] = ()  # hymba: layers that stay full-attn
    rope_theta: float = 10000.0
    # --- hybrid (hymba) ---
    num_meta_tokens: int = 0
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    max_source_positions: int = 0    # stub frame-embedding count
    mlp_activation: str = "swiglu"   # swiglu | gelu
    # --- vlm stub ---
    num_patches: int = 0
    patch_embed_dim: int = 0         # incoming (pre-projection) patch dim
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    source: str = ""                 # provenance note [source; tier]

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state_dim else 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """Whether the arch supports long-context decode (per-step state
        independent of, or sub-linear in, context length)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch decodes (whisper is enc-dec)

    def param_count(self) -> int:
        """Analytic parameter count N (total, incl. all experts)."""
        d, h, kv, hd, f, v, L = (self.d_model, self.num_heads, self.num_kv_heads,
                                 self.head_dim, self.d_ff, self.vocab_size,
                                 self.num_layers)
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            din, ns = self.d_inner, self.ssm_state_dim
            ng, nh = self.ssm_num_groups, self.ssm_num_heads
            in_proj = d * (2 * din + 2 * ng * ns + nh)
            per_layer = in_proj + (din + 2 * ng * ns) * self.ssm_conv_width \
                + 2 * nh + din + din * d + d  # A,D, gate-norm, out_proj, norm
        else:
            attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            if self.family == "moe":
                mlp = self.num_experts * 3 * d * self.expert_d_ff + d * self.num_experts
            elif self.mlp_activation == "gelu":
                mlp = 2 * d * f
            else:
                mlp = 3 * d * f
            per_layer = attn + mlp + 2 * d
            if self.family == "hybrid" and self.ssm_state_dim:
                din, ns, nh = self.d_inner, self.ssm_state_dim, self.ssm_num_heads
                per_layer += d * (2 * din + 2 * ns + nh) \
                    + (din + 2 * ns) * self.ssm_conv_width + 2 * nh + din * d
        total = emb + L * per_layer
        if self.encoder_layers:
            enc_attn = 2 * (d * h * hd + d * kv * hd)
            enc = self.encoder_layers * (enc_attn + 2 * d * f + 2 * d)
            dec_cross = self.num_layers * (2 * (d * h * hd + d * kv * hd) + d)
            total += enc + dec_cross
        return int(total)

    def active_param_count(self) -> int:
        """N_active: params touched per token (MoE routes top-k of E)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        all_experts = L * self.num_experts * 3 * d * self.expert_d_ff
        active = L * self.experts_per_token * 3 * d * self.expert_d_ff
        return int(self.param_count() - all_experts + active)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# --- assigned shape set (LM transformer family) ---------------------------
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # Import the per-arch modules exactly once (they self-register).
    import repro.configs.archs  # noqa: F401


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """The assigned shape cells that are well-defined for this arch.

    ``long_500k`` requires sub-quadratic attention (SSM / hybrid / sliding
    window); pure full-attention archs skip it (recorded in DESIGN.md).
    """
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.is_subquadratic:
            continue
        out.append(s)
    return out


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.family == "moe":
        # capacity_factor = E makes the reduced config fully dropless so
        # prefill/decode paths are bit-comparable in tests.
        kw.update(num_experts=4, experts_per_token=2, moe_d_ff=32,
                  capacity_factor=4.0)
    if cfg.ssm_state_dim:
        kw.update(ssm_state_dim=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, max_source_positions=16)
    if cfg.num_patches:
        kw.update(num_patches=4, patch_embed_dim=32)
    if cfg.num_meta_tokens:
        kw.update(num_meta_tokens=8)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    if cfg.global_attn_layers:
        kw.update(global_attn_layers=(0,))
    return replace(cfg, **kw)


SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")
SMOKE_DECODE_SHAPE = ShapeConfig("smoke_decode", seq_len=32, global_batch=4, kind="decode")
