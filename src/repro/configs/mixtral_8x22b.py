"""mixtral-8x22b — MoE 8 experts top-2 with sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig, register

MIXTRAL_8X22B = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    moe_d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    source="[arXiv:2401.04088; hf]",
))
