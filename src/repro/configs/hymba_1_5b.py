"""hymba-1.5b — hybrid: parallel attention + mamba heads per layer, meta
tokens, mostly sliding-window attention with a few global layers.
[arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig, register

HYMBA_1_5B = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state_dim=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    num_meta_tokens=128,
    tie_embeddings=True,
    source="[arXiv:2411.13676; hf]",
))
