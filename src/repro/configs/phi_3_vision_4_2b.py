"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stubbed: the
assignment feeds precomputed patch embeddings via input_specs()).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.configs.base import ModelConfig, register

PHI_3_VISION_4_2B = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,   # MHA (kv == heads)
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    num_patches=576,       # 24x24 CLIP-L/14 @336px grid (stub frontend)
    patch_embed_dim=1024,  # CLIP-L hidden size before projection
    rope_theta=10000.0,
    source="[hf:microsoft/Phi-3-vision-128k-instruct; hf]",
))
