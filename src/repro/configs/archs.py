"""Import side-effect module: loads every per-arch config file so the
registry in ``repro.configs.base`` is populated."""
import repro.configs.yi_34b  # noqa: F401
import repro.configs.qwen2_0_5b  # noqa: F401
import repro.configs.mistral_large_123b  # noqa: F401
import repro.configs.qwen3_1_7b  # noqa: F401
import repro.configs.granite_moe_3b_a800m  # noqa: F401
import repro.configs.mixtral_8x22b  # noqa: F401
import repro.configs.mamba2_780m  # noqa: F401
import repro.configs.phi_3_vision_4_2b  # noqa: F401
import repro.configs.whisper_large_v3  # noqa: F401
import repro.configs.hymba_1_5b  # noqa: F401
