"""Explicit (manual) data parallelism for the train step.

Why: under plain pjit the microbatch accumulation loop re-pays every DP
collective per microbatch — the v0 dry-run showed per-layer-per-microbatch
f32 weight all-gathers (FSDP re-gather) and weight-grad all-reduces (480x
per step on yi-34b; EXPERIMENTS.md §Perf).  Wrapping the loop in
``shard_map`` over the batch axes makes the DP communication explicit:

* FSDP params (dims sharded over the data axis) are all-gathered in **bf16**
  at use (per layer inside the scan); the gather's transpose is a bf16
  psum_scatter — the *minimal* per-microbatch communication;
* every other leaf's grad is accumulated locally and psum'ed ONCE per step
  (deferred DP sync), not once per microbatch;
* the 'model' mesh axis stays in auto (GSPMD) mode, so the tensor-parallel
  annotations inside the layers keep working unchanged.

``sharding_rules.ShardingCtx.manual_region`` makes ``constrain`` ignore the
manual axes while tracing inside the region.

Divisibility contract: inside the region local shapes can't distinguish "dim
was divided" from "dim was dropped (replicated)", so the gather plan is
rule-based and ``validate_manual_divisibility`` asserts at build time that
every manual-mapped param dim divides cleanly (true for all 10 assigned
archs; a violating config falls back to the legacy pjit step).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding_rules import ShardingCtx, current_ctx

MANUAL_CANDIDATES = ("pod", "data")     # batch-parallel mesh axes


def manual_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in MANUAL_CANDIDATES if a in mesh.shape)


def manual_size(mesh) -> int:
    n = 1
    for a in manual_axes(mesh):
        n *= mesh.shape[a]
    return n


def _is_axes_leaf(t):
    return isinstance(t, tuple) and all(a is None or isinstance(a, str)
                                        for a in t)


def rule_manual_dims(ctx: ShardingCtx, axes, manual
                     ) -> Dict[int, Tuple[str, ...]]:
    """dim -> manual mesh axes that shard it per the rules (axis used once,
    first dim wins — mirrors ``ShardingCtx.partition_spec`` ordering)."""
    out: Dict[int, Tuple[str, ...]] = {}
    used = set()
    for i, name in enumerate(axes):
        mesh_ax = ctx.mesh_axes_for(name, include_manual=True)
        m = tuple(a for a in mesh_ax if a in manual and a not in used)
        if m:
            out[i] = m
            used.update(m)
    return out


def validate_manual_divisibility(ctx: ShardingCtx, axes_tree, abstract_tree,
                                 manual) -> bool:
    """True iff every manual-mapped param dim divides cleanly on the GLOBAL
    shapes (so rule-based gathers inside the region are unambiguous)."""
    ok = [True]

    def one(ax, ab):
        for i, m in rule_manual_dims(ctx, ax, manual).items():
            n = 1
            for a in m:
                n *= ctx.mesh.shape[a]
            if ab.shape[i] % n:
                ok[0] = False

    jax.tree_util.tree_map(one, axes_tree, abstract_tree,
                           is_leaf=_is_axes_leaf)
    return ok[0]


def manual_pspec(ctx: ShardingCtx, axes, manual, ndim: int) -> P:
    """PartitionSpec restricted to manual axes (shard_map in/out specs)."""
    dims = rule_manual_dims(ctx, axes, manual)
    entries: list = []
    for i in range(ndim):
        m = dims.get(i, ())
        entries.append(m[0] if len(m) == 1 else (tuple(m) or None))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_manual_specs(ctx: ShardingCtx, axes_tree, abstract_tree, manual):
    return jax.tree_util.tree_map(
        lambda ax, ab: manual_pspec(ctx, ax, manual, len(ab.shape)),
        axes_tree, abstract_tree, is_leaf=_is_axes_leaf)


def gather_leaf(x, dims: Dict[int, Tuple[str, ...]], *,
                dtype: Optional[Any] = None,
                auto_entries: Optional[Sequence] = None,
                wrap_axes: Tuple[str, ...] = ()):
    """all_gather a leaf's manual-sharded dims (optionally casting first, so
    FSDP gathers move bf16 not f32 — half the wire bytes; the cast's
    transpose restores an f32 shard cotangent).

    The gather always runs inside a fully-manual inner shard_map over the
    remaining auto axes: differentiating a convert feeding an all_gather
    under a PARTIAL-manual mesh crashes the XLA SPMD partitioner ("Invalid
    binary instruction opcode copy" — minimal repro in
    tests/test_distributed.py); with every mesh axis manual around the
    collective the mixed-mode transpose never forms.  ``auto_entries``
    carries the leaf's own TP sharding into the wrap; ``wrap_axes`` supplies
    a throwaway auto axis for leaves with none.  If the mesh has no auto
    axis at all, gather f32 and cast after (the known-safe order)."""
    if not dims:
        return x if dtype is None else x.astype(dtype)

    def ag(t):
        for dim, axes in sorted(dims.items()):
            for a in reversed(axes):
                t = jax.lax.all_gather(t, a, axis=dim, tiled=True)
        return t

    auto_used = tuple(a for e in (auto_entries or ())
                      for a in ((e,) if isinstance(e, str) else (e or ())))
    if not auto_used and dtype is not None and not wrap_axes:
        return ag(x).astype(dtype)          # no auto axis: safe order
    if dtype is not None and x.dtype != dtype:
        x = x.astype(dtype)
    if not auto_used and not wrap_axes:
        return ag(x)
    names = set(auto_used) or {wrap_axes[0]}
    spec = P(*auto_entries) if auto_entries else P()
    return jax.shard_map(ag, in_specs=(spec,), out_specs=spec,
                         axis_names=names, check_vma=False)(x)


def _auto_entries(ctx, ax, shape, manual):
    """Per-dim AUTO mesh axes actually sharding this leaf (rule + dim
    divisibility on the body-visible shape — auto dims are global there)."""
    entries = []
    used: set = set()
    any_used = False
    for i, name in enumerate(ax):
        axes = tuple(a for a in ctx.mesh_axes_for(name, include_manual=True)
                     if a not in manual and a not in used)
        kept = []
        n = 1
        for a in axes:
            sz = ctx.mesh.shape[a]
            if shape[i] % (n * sz) == 0:
                kept.append(a)
                n *= sz
        used.update(kept)
        any_used = any_used or bool(kept)
        entries.append(kept[0] if len(kept) == 1 else (tuple(kept) or None))
    while entries and entries[-1] is None:
        entries.pop()
    return entries if any_used else None


def _gather_tree(tree, axes_tree, ctx, manual, *, skip_layers_dim: bool,
                 compute_dtype):
    wrap_axes = tuple(a for a in ctx.mesh.shape if a not in manual)

    def one(ax, x):
        if skip_layers_dim and ax and ax[0] == "layers":
            return x                      # per-layer hook handles these
        dims = rule_manual_dims(ctx, ax, manual)
        if not dims:
            return x
        dt = compute_dtype if x.ndim >= 2 else None   # 1D: keep f32
        return gather_leaf(x, dims, dtype=dt,
                           auto_entries=_auto_entries(ctx, ax, x.shape,
                                                      manual),
                           wrap_axes=wrap_axes)

    return jax.tree_util.tree_map(one, axes_tree, tree, is_leaf=_is_axes_leaf)


def gather_params(params, axes_tree, *, compute_dtype=jnp.bfloat16):
    """Gather manual-sharded dims of every NON-stacked leaf (stacked leaves
    — leading logical axis 'layers' — are gathered per layer inside the scan
    by ``layer_hook``).  No-op outside a manual region."""
    ctx = current_ctx()
    if ctx is None or not ctx.manual:
        return params
    return _gather_tree(params, axes_tree, ctx, ctx.manual,
                        skip_layers_dim=True, compute_dtype=compute_dtype)


def layer_hook(axes_tree, *, compute_dtype=jnp.bfloat16):
    """Per-layer FSDP gather for ``stack.run_stack``: gathers the scanned
    per-layer param slice's manual-sharded dims (bf16 for 2D+ leaves).
    ``axes_tree`` is the per-layer (unstacked) logical-axes tree."""
    def hook(p_layer):
        ctx = current_ctx()
        if ctx is None or not ctx.manual:
            return p_layer
        return _gather_tree(p_layer, axes_tree, ctx, ctx.manual,
                            skip_layers_dim=False,
                            compute_dtype=compute_dtype)
    return hook


def deferred_psum(grads, axes_tree, ctx: ShardingCtx, manual, scale):
    """One-per-step DP gradient sync.  Leaves with a manual-sharded dim were
    already reduced over those axes by the FSDP gather's psum_scatter
    transpose; they (and everything else) still need the psum over the
    REMAINING manual axes (e.g. 'pod' when only 'data' shards them)."""
    def one(ax, g):
        dims = rule_manual_dims(ctx, ax, manual)
        used = set(a for axes in dims.values() for a in axes)
        rest = tuple(a for a in manual if a not in used)
        if rest:
            g = jax.lax.psum(g, rest)
        return g * scale

    return jax.tree_util.tree_map(one, axes_tree, grads,
                                  is_leaf=_is_axes_leaf)
