"""Int8 error-feedback gradient compression for the DP all-reduce.

Distributed-optimization trick for the multi-pod mesh: the data-parallel
gradient all-reduce moves fp32 bytes; quantizing to int8 with per-tensor
scale cuts DP traffic 4x.  Quantization error is carried in an error-
feedback accumulator (Seide et al. / EF-SGD), which preserves convergence —
verified by tests/test_grad_compress.py (toy regression converges to the
same loss) and usable per-axis (compress only the slow 'pod' axis).

Inside jit, XLA sees: quantize -> psum(int32) -> dequantize, so the wire
format of the all-reduce really is 8-bit payload (accumulate in i32).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g, err):
    """Local quantize/dequantize with error feedback (the lossy channel the
    all-reduce payload passes through).  Returns (g_hat, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    g_hat = dequantize_int8(q, scale)
    return g_hat, corrected - g_hat


def compressed_psum(g, err, axis_names) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: error-feedback int8 all-reduce over ``axis_names``.

    The psum runs on the int32-accumulated quantized payload; scales are
    psum-maxed.  Returns (mean_gradient, new_error)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    local_dq = dequantize_int8(q, scale)
    new_err = corrected - local_dq
    # shared scale: max over the axis so every shard dequantizes consistently
    scale_max = jax.lax.pmax(scale, axis_names)
    q2 = jnp.clip(jnp.round(corrected / scale_max), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_names)
    size = jax.lax.psum(jnp.ones(()), axis_names)
    return total.astype(jnp.float32) * scale_max / size, new_err


def compress_tree(grads, err_tree):
    """Whole-pytree local compression channel (used by the trainer when the
    mesh is single-host: models the wire without collectives)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    g_hat = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return g_hat, new_e
