"""Logical-axis -> mesh-axis sharding rules (MaxText-style), with an
auto-divisibility guard so every (arch x shape x mesh) cell compiles.

Parameters and activations are annotated with *logical* axis names; a rule set
maps those to physical mesh axes.  ``build_sharding`` drops any mesh axis that
does not evenly divide the corresponding dimension (e.g. granite's vocab=49155
on a 16-way model axis) and records the drop, instead of failing to lower —
such drops are replication, which is always correct, and the roofline report
surfaces the cost.
"""
from __future__ import annotations

import contextlib
import contextvars
import logging
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

logger = logging.getLogger(__name__)

MeshAxes = Union[None, str, Tuple[str, ...]]

# --- rule sets -------------------------------------------------------------
# batch-like axes shard over ("pod","data") when the pod axis exists; the
# helper filters mesh axes that are absent from the mesh, so one rule set
# serves single-pod and multi-pod meshes.

TRAIN_RULES: Dict[str, MeshAxes] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_res": "model",        # residual stream between layers (manual-SP:
                               # stack.run_stack gathers before attention/MLP
                               # and reduce-scatters their outputs)
    "kv_seq": None,
    "qkv": "model",            # flattened heads*head_dim activation dim
    "heads_act": "model",      # per-head activation dim (guarded: replicates
    "kv_heads_act": "model",   # when head count doesn't divide the axis)
    "mlp_act": "model",
    "embed_act": None,
    "vocab_act": "model",
    "experts_act": None,
    "moe_cap": ("pod", "data"),    # MoE dispatch capacity slots (DP-sharded)
    "ssm_inner_act": "model",
    # params
    "vocab": "model",
    "embed": "data",           # FSDP: gather-per-layer under scan
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": None,
    "experts_virt": "model",   # virtual EP layout (E<16 archs; see layers.moe)
    "expert_mlp": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "layers": None,
    "pos": None,
}

# Megatron-style sequence parallelism for the residual stream: norms/embeds
# run on seq-sharded activations; enabled for long-sequence training cells.
TRAIN_SP_RULES = dict(TRAIN_RULES, seq="model")

# Serving: weight-stationary sharding — params replicated over the batch
# axes (no optimizer state to amortize; per-step FSDP gathers would
# dominate decode latency) and TP over model; batch over data; KV-cache
# *sequence* dim over model (flash-decoding style partial softmax —
# kv-head counts don't divide 16, seq always does).
SERVE_RULES: Dict[str, MeshAxes] = dict(
    TRAIN_RULES,
    batch=("pod", "data"),
    kv_seq="model",
    embed=None,
    seq_res=None,
    vocab="model",
)

# >20B params: bf16 weights / 16-way TP crowd HBM next to the KV cache, so
# serving keeps the FSDP data-axis sharding and pays per-layer bf16 gathers
# (mistral-large: 15.4 GiB/dev replicated vs 1 GiB sharded + 0.3 s/token of
# gather wire — the capacity/latency trade recorded in DESIGN.md).
SERVE_RULES_BIG = dict(SERVE_RULES, embed="data")

# Long-context prefill: shard the sequence dimension as well.
PREFILL_RULES = dict(SERVE_RULES, seq=None)
PREFILL_RULES_BIG = dict(SERVE_RULES_BIG, seq=None)


_ACTIVE: contextvars.ContextVar = contextvars.ContextVar("sharding_ctx",
                                                         default=None)


class ShardingCtx:
    def __init__(self, mesh: Mesh, rules: Dict[str, MeshAxes]):
        self.mesh = mesh
        self.rules = dict(rules)
        self.dropped: list = []
        # mesh axes currently under manual (shard_map) control: constrain()
        # and partition_spec() must not mention them (the array dims they
        # shard are already local inside the manual region).
        self.manual: frozenset = frozenset()

    @contextlib.contextmanager
    def manual_region(self, axes):
        prev = self.manual
        self.manual = frozenset(axes) | prev
        try:
            yield self
        finally:
            self.manual = prev

    def mesh_axes_for(self, logical: Optional[str],
                      *, include_manual: bool = False) -> Tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.rules.get(logical)
        if axes is None:
            return ()
        if isinstance(axes, str):
            axes = (axes,)
        out = tuple(a for a in axes if a in self.mesh.shape)
        if not include_manual:
            out = tuple(a for a in out if a not in self.manual)
        return out

    def partition_spec(self, logical_axes: Sequence[Optional[str]],
                       dims: Optional[Sequence[int]] = None) -> P:
        """Map logical axes to a PartitionSpec; drop non-dividing mesh axes."""
        entries = []
        used = set()
        for i, name in enumerate(logical_axes):
            axes = self.mesh_axes_for(name)
            axes = tuple(a for a in axes if a not in used)
            if dims is not None and axes:
                shards = 1
                kept = []
                for a in axes:
                    n = self.mesh.shape[a]
                    if dims[i] % (shards * n) == 0:
                        kept.append(a)
                        shards *= n
                    else:
                        self.dropped.append((name, a, dims[i]))
                axes = tuple(kept)
            used.update(axes)
            if not axes:
                entries.append(None)
            elif len(axes) == 1:
                entries.append(axes[0])
            else:
                entries.append(axes)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def named_sharding(self, logical_axes, dims=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.partition_spec(logical_axes, dims))


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict[str, MeshAxes]):
    ctx = ShardingCtx(mesh, rules)
    token = _ACTIVE.set(ctx)
    try:
        with mesh:
            yield ctx
    finally:
        _ACTIVE.reset(token)


def current_ctx() -> Optional[ShardingCtx]:
    return _ACTIVE.get()


def constrain(x, *logical_axes: Optional[str]):
    """with_sharding_constraint by logical activation axes; no-op outside a
    ``use_rules`` context (so smoke tests on 1 device run unannotated).

    Inside a manual region (shard_map over the DP axes) the constraint uses
    a bare PartitionSpec — the context's abstract mesh — and never mentions
    manual axes (``mesh_axes_for`` filters them)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"constrain rank mismatch: {logical_axes} vs {x.shape}")
    pspec = ctx.partition_spec(logical_axes, x.shape)
    if ctx.manual:
        return jax.lax.with_sharding_constraint(x, pspec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, pspec))


def param_shardings(specs_logical_axes, abstract, mesh: Mesh,
                    rules: Dict[str, MeshAxes]):
    """Sharding tree for a param pytree given its logical-axes tree."""
    ctx = ShardingCtx(mesh, rules)
    return jax.tree_util.tree_map(
        lambda axes, arr: ctx.named_sharding(axes, arr.shape),
        specs_logical_axes, abstract,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t),
    )


def rules_for(kind: str, *, seq_parallel: bool = False,
              big_params: bool = False) -> Dict[str, MeshAxes]:
    if kind == "train":
        return TRAIN_SP_RULES if seq_parallel else TRAIN_RULES
    if kind == "prefill":
        return PREFILL_RULES_BIG if big_params else PREFILL_RULES
    if kind == "decode":
        return SERVE_RULES_BIG if big_params else SERVE_RULES
    raise ValueError(kind)
