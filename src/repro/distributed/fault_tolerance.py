"""Fault tolerance: heartbeats, straggler detection, failure-driven restart
and elastic re-mesh planning.

On a real fleet these hooks sit next to the coordinator (GCS / etcd); here
they are in-process with injectable clocks so the behaviour — detection
thresholds, restart decisions, re-mesh math — is testable deterministically.
The Trainer wires them in: per-step durations feed the StragglerDetector
(which can trigger a DPT re-tune on the slow host — the paper's knobs are
exactly what drifts when a host degrades), heartbeats feed the
HeartbeatRegistry, and a detected failure produces an ElasticPlan that maps
(surviving hosts, old mesh) -> (new mesh, resharded restore).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple


class HeartbeatRegistry:
    def __init__(self, *, timeout_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self._last: Dict[str, float] = {}

    def beat(self, host: str) -> None:
        self._last[host] = self.clock()

    def remove(self, host: str) -> None:
        """Forget a host (it was declared dead and resharded around, or it
        left gracefully) so it stops appearing in ``dead_hosts``."""
        self._last.pop(host, None)

    def hosts(self) -> List[str]:
        return sorted(self._last)

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)

    def alive_hosts(self) -> List[str]:
        dead = set(self.dead_hosts())
        return sorted(h for h in self._last if h not in dead)

    def state_dict(self) -> Dict[str, float]:
        """Last-beat ages (now - last), not absolute times: a restoring
        coordinator may run on a different clock origin."""
        now = self.clock()
        return {h: now - t for h, t in self._last.items()}

    def load_state(self, ages: Dict[str, float]) -> None:
        now = self.clock()
        self._last = {h: now - float(a) for h, a in ages.items()}

    def rearm(self, hosts: Sequence[str]) -> None:
        """Re-beat every host at NOW — used after failover so the outage
        window does not count against host liveness (a genuinely dead
        host simply times out once more)."""
        now = self.clock()
        for h in hosts:
            self._last[h] = now


class StragglerDetector:
    """Rolling-window per-host step times; a host is a straggler when its
    median exceeds ``threshold`` x the fleet median."""

    def __init__(self, *, window: int = 16, threshold: float = 1.5):
        self.window = window
        self.threshold = threshold
        self._times: Dict[str, deque] = defaultdict(
            lambda: deque(maxlen=self.window))

    def record(self, host: str, seconds: float) -> None:
        self._times[host].append(seconds)

    def forget(self, host: str) -> None:
        """Drop a departed host's window (its stale medians would otherwise
        skew the fleet median forever)."""
        self._times.pop(host, None)

    @staticmethod
    def _median(xs: Sequence[float]) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def medians(self) -> Dict[str, float]:
        return {h: self._median(list(t)) for h, t in self._times.items() if t}

    def stragglers(self) -> List[str]:
        meds = self.medians()
        if len(meds) < 2:
            return []
        fleet = self._median(list(meds.values()))
        return sorted(h for h, m in meds.items()
                      if m > self.threshold * fleet)

    def state_dict(self) -> Dict[str, List[float]]:
        return {h: list(t) for h, t in self._times.items()}

    def load_state(self, windows: Dict[str, List[float]]) -> None:
        self._times.clear()
        for h, xs in windows.items():
            self._times[h].extend(float(x) for x in xs[-self.window:])


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Re-mesh decision after host loss."""
    old_hosts: int
    new_hosts: int
    new_data_axis: int               # devices along the data axis
    new_global_batch: int            # keep per-device batch constant
    restore_step: Optional[int]
    feasible: bool
    reason: str = ""


def plan_remesh(*, alive_hosts: int, devices_per_host: int, model_axis: int,
                old_hosts: int, old_global_batch: int,
                restore_step: Optional[int]) -> ElasticPlan:
    """Elastic scaling: keep the model axis intact (TP degree is dictated by
    memory), shrink the data axis to the surviving hosts, and scale the
    global batch to keep per-device batch constant (linear-scaling rule —
    the LR schedule is re-scaled by the Trainer accordingly).
    """
    total = alive_hosts * devices_per_host
    if total % model_axis:
        return ElasticPlan(old_hosts, alive_hosts, 0, 0, restore_step,
                           feasible=False,
                           reason=f"{total} devices not divisible by "
                                  f"model axis {model_axis}")
    new_data = total // model_axis
    old_data = old_hosts * devices_per_host // model_axis
    per_replica = old_global_batch / max(1, old_data)
    new_batch = int(round(per_replica * new_data))
    if new_batch == 0:
        return ElasticPlan(old_hosts, alive_hosts, new_data, 0, restore_step,
                           feasible=False, reason="batch would be 0")
    # The rounded batch can land on a value the sampler cannot shard
    # uniformly (ShardedSampler requires global_batch % host_count == 0
    # for a uniform split).  Snap to the nearest positive multiple of the
    # survivor count so the plan is always directly applicable, and leave
    # an audit trail of the adjustment.
    reason = ""
    if new_batch % alive_hosts:
        snapped = max(alive_hosts,
                      int(round(new_batch / alive_hosts)) * alive_hosts)
        reason = (f"snapped global batch {new_batch} -> {snapped} "
                  f"(nearest multiple of {alive_hosts} hosts)")
        new_batch = snapped
    return ElasticPlan(old_hosts, alive_hosts, new_data, new_batch,
                       restore_step, feasible=True, reason=reason)


class FailureInjector:
    """Deterministic failure schedule for tests/examples:
    ``{step: [host, ...]}`` marks hosts dead at a given step."""

    def __init__(self, schedule: Dict[int, Sequence[str]]):
        self.schedule = dict(schedule)
        self.dead: Set[str] = set()

    def advance(self, step: int) -> List[str]:
        newly = list(self.schedule.get(step, []))
        self.dead.update(newly)
        return newly
