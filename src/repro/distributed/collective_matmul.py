"""Collective (overlapped all-gather) matmul via shard_map + ppermute.

Beyond-paper distributed-optimization trick for the TP axis: instead of
``all_gather(x) @ w`` (a bandwidth burst, then compute), the gather is
decomposed into ring steps — each step matmuls the shard it already holds
while ppermute-ing the next shard around the ring, hiding ICI latency
behind the MXU ("Overlap Communication with Computation", Wang et al.).

Used by the perf hillclimb when the roofline shows the collective term
dominating a TP matmul; correctness is asserted against the plain gather
matmul in tests/test_collectives.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def ring_weight_matmul(x, w, mesh: Mesh, *, axis: str = "model"):
    """x: (m, k) sharded on m over ``axis``; w: (k, f) sharded on f.

    Computes x @ w (m-sharded, f-replicated result per shard of m) while
    ring-rotating weight shards so each ICI transfer overlaps one local
    matmul.  Equivalent to jnp.dot(x, w) (tested)."""
    n = mesh.shape[axis]
    f = w.shape[1]
    assert f % n == 0, (f, n)

    def body_fn(x_local, w_local):
        idx = jax.lax.axis_index(axis)
        nloc = jax.lax.psum(1, axis)
        perm = [(i, (i + 1) % nloc) for i in range(n)]
        fs = w_local.shape[1]

        def step(i, carry):
            out, wblk = carry
            src = (idx - i) % nloc          # which f-slice this block is
            part = jnp.dot(x_local, wblk,
                           preferred_element_type=jnp.float32)
            out = jax.lax.dynamic_update_slice(out, part, (0, src * fs))
            wblk = jax.lax.ppermute(wblk, axis, perm)
            return out, wblk

        out0 = jnp.zeros((x_local.shape[0], f), jnp.float32)
        if hasattr(jax.lax, "pvary"):  # shard_map vma typing (jax >= 0.6)
            out0 = jax.lax.pvary(out0, (axis,))
        out, _ = jax.lax.fori_loop(0, n, step, (out0, w_local))
        return out

    return shard_map(
        body_fn, mesh=mesh,
        in_specs=(P(axis, None), P(None, axis)),
        out_specs=P(axis, None),
    )(x, w)
