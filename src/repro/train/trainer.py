"""Trainer: the end-to-end loop that makes DPT a first-class framework
feature rather than an offline script.

Startup:  restore latest checkpoint (step + sampler offset + loader params)
          -> DPT-tune the loader (or reuse the cached result for this
          machine/dataset fingerprint) -> jit the train step.
Steady:   device-prefetched batches -> train step; per-step wall time feeds
          the StragglerDetector; every ``checkpoint_every`` steps an async
          checkpoint (params, opt state, sampler state, loader params).
Drift:    an OnlineTuner (repro.tuning.online) watches the per-step
          data-wait vs compute-time goodput signal; when the loader
          becomes the bottleneck it runs a bounded re-search and
          hot-swaps the winner into the live stream (no rebuild, no lost
          batches) — the online re-tuning the paper's conclusion gestures
          at for clouds.
Fleet:    on a coordinated fleet the Trainer is constructed with a
          HostAgent (repro.tuning.fleet) instead: the same goodput signal
          streams to the FleetCoordinator (doubling as the heartbeat),
          which owns the decide step — uniform re-consensus and elastic
          resharding arrive back through the agent's apply_params /
          reshard.  The local OnlineTuner is disabled in that mode so
          host-local and fleet-level retunes can never fight.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.cache import DPTCache
from repro.core.dpt import DPTConfig
from repro.core.evaluators import LoaderEvaluator
from repro.data.loader import DataLoader, LoaderParams
from repro.distributed.fault_tolerance import StragglerDetector
from repro.train.train_step import (TrainState, TrainStepConfig,
                                    init_train_state, make_train_step)
from repro.tuning import (OnlineTuner, OnlineTunerConfig, adaptive_budget,
                          tune)
from repro.utils.fingerprint import machine_fingerprint


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0
    # DPT integration (startup tune + online retune, see repro.tuning)
    autotune: bool = True
    autotune_strategy: str = "grid"
    # None derives the per-cell budget adaptively (>= 3x the deepest
    # worker rung — see tuning.base.adaptive_budget)
    autotune_budget_batches: Optional[int] = None
    autotune_max_prefetch: int = 4
    # candidate sampler locality_chunk values for the startup grid
    # (DESIGN.md §5).  None keeps the search on the paper's two axes;
    # include 0 in the tuple so fully-random order stays a candidate —
    # warm/CPU-bound profiles should be free to reject chunking.
    # Single-host only: on a sharded fleet the axis is ignored (every host
    # must slice the SAME epoch permutation, so locality can only change
    # uniformly via the coordinator, never from a per-host tune).
    autotune_locality_chunks: Optional[tuple] = None
    # candidate cache_budget_bytes values for the startup grid's fourth
    # axis (DESIGN.md §7).  None keeps the cache tier off the search;
    # include 0 in the tuple so "no cache" stays a candidate.  Single-host
    # startup only, same as locality: on a fleet the budget changes
    # uniformly through the coordinator (FleetConfig.cache_budgets).
    autotune_cache_budgets: Optional[tuple] = None
    # candidate slow_lane_workers values for the startup grid's fifth
    # axis (DESIGN.md §9).  None keeps the dual lane off the search;
    # include 0 in the tuple so "no slow lane" stays a candidate.  The
    # lane is HOST-LOCAL machinery (it never touches the sampler's epoch
    # permutation, only which worker decodes a batch), so unlike locality
    # and cache this axis needs no multi-host guard — only the
    # grid-strategy guard applies.
    autotune_slow_lanes: Optional[tuple] = None
    # retune trigger on the per-item cost tail ratio (p99/median of the
    # loader's tracked per-item costs, ~1 uniform; see DESIGN.md §9).
    # 0 disables; only armed when autotune_slow_lanes is set.
    retune_tail_ratio_trigger: float = 0.0
    # retune trigger on the loader's windowed fault rate (DESIGN.md §10):
    # fires a re-search when the storage browns out and once more when
    # degraded mode heals.  0 disables.
    retune_fault_rate_trigger: float = 0.0
    # the online locality loop (DESIGN.md §6): when True, an
    # AdaptiveLocalityController watches the live coalesced-run-length
    # counters and shrinks locality_chunk when the storage stops
    # achieving it (cache warmed, topology changed) — no search, applied
    # as an epoch-latched hot swap.  On a fleet the proposal routes to
    # the coordinator instead (locality must change uniformly).  The
    # single-host OnlineTuner also sweeps autotune_locality_chunks at
    # retune time, so the knob can climb back UP when storage slows.
    adaptive_locality: bool = False
    retune_stall_fraction: float = 0.5   # data-wait/compute drift trigger
    retune_window: int = 8
    retune_cooldown_steps: int = 16
    dpt_cache_path: Optional[str] = None
    # zero-copy slab-arena delivery (DESIGN.md §3).  Default ON: the train
    # loop consumes device batches through the prefetcher (which transfers
    # before the slab recycles) and never retains a host view, so the
    # batch-lifetime contract holds.  Silently inert for datasets without
    # the fast path or for process pools.
    zero_copy: bool = True
    # linear-scaling rule (DESIGN.md §11): when the elastic geometry latch
    # changes the loader's global batch mid-run (a fleet reshard scaled
    # the fleet), scale the LR schedule by new/old and re-jit the step.
    # plan_remesh promises exactly this hand-off ("the LR schedule is
    # re-scaled by the Trainer accordingly").
    lr_linear_scaling: bool = True
    step_config: TrainStepConfig = dataclasses.field(
        default_factory=TrainStepConfig)


class Trainer:
    def __init__(self, model, loader: DataLoader, cfg: TrainerConfig,
                 *, host_name: str = "host0", agent=None):
        self.model = model
        self.loader = loader
        self.cfg = cfg
        self.host_name = host_name
        # fleet mode: a repro.tuning.fleet.HostAgent — observations stream
        # to the coordinator and the local OnlineTuner stays off
        self.agent = agent
        self.checkpointer = Checkpointer(cfg.checkpoint_dir) \
            if cfg.checkpoint_dir else None
        self.straggler = StragglerDetector()
        self.step_fn = jax.jit(make_train_step(model, cfg.step_config))
        self.state: Optional[TrainState] = None
        self.start_step = 0
        # reference batch for the linear-scaling LR hook: the geometry the
        # current step_fn's schedule was built for
        self._lr_batch = loader.global_batch
        self.online_tuner: Optional[OnlineTuner] = None
        self.locality_controller = None
        self.history: List[Dict[str, Any]] = []

    def connect_fleet(self, transport, *, join: bool = False,
                      coord: str = "coord", link_config=None,
                      clock=time.monotonic):
        """Attach this trainer to a fleet over a message transport.

        Builds a transport-attached HostAgent around ``self.loader`` and
        registers (or ``join=True`` mid-run admits) it with the
        coordinator endpoint.  After this, ``run()`` streams observations
        over the wire and the coordinator's pushes (params, reshards,
        schedules) arrive as fenced commands — and a coordinator outage
        never blocks the step loop: the host trains on its last
        latched params and re-syncs on reconnect."""
        from repro.tuning.fleet import connect_host
        self.agent = connect_host(
            transport, self.host_name, self.loader, coord=coord,
            link_config=link_config, clock=clock, join=join)
        return self.agent

    # ---- DPT integration ----------------------------------------------------
    def tune_loader(self, *, force: bool = False) -> LoaderParams:
        """Startup tune through the unified ``tune(...)`` front door (or
        reuse the cached result for this machine/dataset fingerprint)."""
        cache = DPTCache(self.cfg.dpt_cache_path)
        mfp = machine_fingerprint()
        dfp = self.loader.dataset.fingerprint()
        strategy = self.cfg.autotune_strategy
        locality_axis = self.cfg.autotune_locality_chunks
        if locality_axis and self.loader.sampler.host_count > 1:
            # per-host tuned chunks would give each host a DIFFERENT epoch
            # permutation, breaking the cross-host coverage invariant the
            # fleet relies on (every host must slice the SAME perm).  A
            # multi-host locality change must arrive uniformly through the
            # coordinator, not the local startup tune.
            locality_axis = None
        if locality_axis and strategy != "grid":
            # only the grid strategy sweeps DPTConfig.locality_chunks; for
            # any other strategy the axis is unsearched and the result's
            # locality_chunk=0 must not be force-applied over the user's
            locality_axis = None
        cache_axis = self.cfg.autotune_cache_budgets
        if cache_axis and (self.loader.sampler.host_count > 1
                           or strategy != "grid"):
            # same guards as locality: the cache plan shapes the epoch
            # permutation (interleaved hot chunks), so a sharded fleet
            # changes the budget uniformly via the coordinator; and only
            # the grid strategy sweeps the axis
            cache_axis = None
        lane_axis = self.cfg.autotune_slow_lanes
        if lane_axis and strategy != "grid":
            # only the grid strategy sweeps DPTConfig.slow_lanes.  No
            # multi-host guard: the lane split is host-local (it never
            # touches the shared epoch permutation)
            lane_axis = None
        cached = None if force else cache.get_params(
            mfp, dfp, self.loader.global_batch,
            require_locality=bool(locality_axis),
            require_cache=bool(cache_axis),
            with_cache=bool(cache_axis),
            require_slow_lane=bool(lane_axis),
            with_slow_lane=bool(lane_axis))
        if cached is not None:
            rep = {"num_workers": cached[0], "prefetch_factor": cached[1]}
            if locality_axis:
                # only adopt a cached locality when this run searches the
                # axis — a 2-axis run must not silently reset a user-set
                # locality_chunk to a stale cached value
                rep["locality_chunk"] = cached[2]
            if cache_axis:
                rep["cache_budget_bytes"] = cached[3]
            if lane_axis:
                # the lane width is the LAST element whenever requested
                rep["slow_lane_workers"] = cached[-1]
            params = self.loader.params.replace(**rep)
            self.loader.with_params(params)
            return params
        ev = LoaderEvaluator(self.loader, to_device=True)
        search_cfg = DPTConfig(max_prefetch=self.cfg.autotune_max_prefetch,
                               locality_chunks=(tuple(locality_axis)
                                                if locality_axis else None),
                               cache_budgets=(tuple(cache_axis)
                                              if cache_axis else None),
                               slow_lanes=(tuple(lane_axis)
                                           if lane_axis else None))
        search_cfg = dataclasses.replace(search_cfg, num_batches=(
            adaptive_budget(search_cfg, self.cfg.autotune_budget_batches)))
        if strategy == "grid":
            kwargs = {"measure_default": False}
        elif strategy == "successive_halving":
            kwargs = {}
        elif strategy == "hillclimb":
            _, G = search_cfg.resolve()
            kwargs = {"start": (max(G, self.loader.params.num_workers),
                                self.loader.params.prefetch_factor)}
        else:
            # goodput needs a measured step time, warmstart needs profiles —
            # neither exists before the first step
            raise ValueError(
                f"autotune_strategy {strategy!r} cannot run at startup; "
                "use 'grid', 'successive_halving' or 'hillclimb'")
        result = tune(evaluator=ev, strategy=strategy,
                      config=search_cfg, **kwargs)
        cache.put(mfp, dfp, self.loader.global_batch, result)
        rep = {"num_workers": result.nworker,
               "prefetch_factor": result.nprefetch}
        if locality_axis:
            rep["locality_chunk"] = result.locality_chunk
        if cache_axis:
            rep["cache_budget_bytes"] = result.cache_budget_bytes
        if lane_axis:
            rep["slow_lane_workers"] = result.slow_lane_workers
        params = self.loader.params.replace(**rep)
        self.loader.with_params(params)
        return params

    def _make_online_tuner(self) -> OnlineTuner:
        # the online locality axis follows the startup grid's candidate
        # set; single-host only (fleet mode never builds a local tuner,
        # and a sharded loader must change locality via the coordinator)
        chunks = self.cfg.autotune_locality_chunks \
            if self.loader.sampler.host_count == 1 else None
        budgets = self.cfg.autotune_cache_budgets \
            if self.loader.sampler.host_count == 1 else None
        # the lane axis is host-local, so it needs no host_count guard
        lanes = self.cfg.autotune_slow_lanes
        return OnlineTuner(
            self.loader,
            evaluator=LoaderEvaluator(self.loader, to_device=True),
            cache=DPTCache(self.cfg.dpt_cache_path),
            config=OnlineTunerConfig(
                stall_fraction=self.cfg.retune_stall_fraction,
                window=self.cfg.retune_window,
                cooldown_steps=self.cfg.retune_cooldown_steps,
                retune_budget_batches=self.cfg.autotune_budget_batches,
                max_prefetch=self.cfg.autotune_max_prefetch,
                locality_chunks=(tuple(chunks) if chunks else None),
                cache_budgets=(tuple(budgets) if budgets else None),
                slow_lanes=(tuple(lanes) if lanes else None),
                tail_ratio_trigger=self.cfg.retune_tail_ratio_trigger,
                fault_rate_trigger=self.cfg.retune_fault_rate_trigger))

    def _make_locality_controller(self):
        """The counter-driven side of the online locality loop: applies
        locally on a single host; on a fleet, a proposal only *signals*
        the coordinator (locality must change uniformly there).  A
        sharded loader WITHOUT an agent gets no controller at all — a
        local resize would hand this host a different epoch permutation
        than its peers (same guard as the startup tune's locality axis).
        """
        from repro.tuning import AdaptiveLocalityController
        if self.agent is None and self.loader.sampler.host_count > 1:
            return None
        on_propose = None
        if self.agent is not None:
            # the coordinator drops the request when the fleet searches
            # no locality axis (a search that can't touch the knob would
            # burn goodput on every repeated proposal)
            on_propose = self.agent.notify_locality
        return AdaptiveLocalityController(self.loader,
                                          on_propose=on_propose)

    # ---- checkpoint/restart ---------------------------------------------------
    def _maybe_restore(self) -> None:
        if self.checkpointer is None or self.checkpointer.latest_step() is None:
            self.state = init_train_state(
                self.model, jax.random.PRNGKey(self.cfg.seed),
                self.cfg.step_config)
            return
        template = init_train_state(
            self.model, jax.random.PRNGKey(self.cfg.seed),
            self.cfg.step_config)
        self.state, aux = self.checkpointer.restore(template)
        self.start_step = int(aux["step"])
        if "loader" in aux:
            self.loader.load_state_dict(aux["loader"])

    def _consumed_state(self, step: int):
        """Sampler state reflecting batches the TRAINER consumed (one per
        step) — the producer runs ahead by worker queues + device prefetch,
        so loader.sampler.state would skip batches on restart.  Walks the
        geometry schedule (batches-per-epoch can differ per epoch after an
        elastic latch), not a fixed bpe."""
        s = self.loader.sampler
        base = s.epoch_start(self._stream_base.epoch) \
            + self._stream_base.batch_offset
        return s.state_at(base + (step - self._stream_base_step))

    def _rebuild_stream(self, step: int):
        """(Re)create the batch iterator from the consumed position."""
        self.loader.sampler.state = self._consumed_state(step) \
            if hasattr(self, "_stream_base") else self.loader.sampler.state
        import copy
        self._stream_base = copy.deepcopy(self.loader.sampler.state)
        self._stream_base_step = step
        return iter(self.loader)

    def _save(self, step: int, block: bool = False) -> None:
        if self.checkpointer is None:
            return
        sd = self.loader.state_dict()
        sd["sampler"] = self._consumed_state(step).to_dict()
        self.checkpointer.save(step, self.state, aux={"loader": sd},
                               block=block)

    def _maybe_rescale_lr(self) -> None:
        """Linear-scaling rule: when the global batch moved (an elastic
        geometry latch crossed an epoch boundary), scale peak_lr by
        new/old and re-jit.  Geometry changes are epoch-rare, so the
        re-jit cost is negligible against an epoch of steps."""
        gb = self.loader.global_batch
        if not self.cfg.lr_linear_scaling or gb == self._lr_batch:
            return
        scale = gb / self._lr_batch
        opt = self.cfg.step_config.optimizer
        self.cfg.step_config = dataclasses.replace(
            self.cfg.step_config,
            optimizer=dataclasses.replace(opt, peak_lr=opt.peak_lr * scale))
        self.step_fn = jax.jit(make_train_step(self.model,
                                               self.cfg.step_config))
        self.history.append({"event": "lr_rescale", "scale": scale,
                             "global_batch": gb,
                             "peak_lr": self.cfg.step_config.optimizer.peak_lr})
        self._lr_batch = gb

    def _apply_delivery_defaults(self) -> None:
        """Flip zero-copy delivery on when the pipeline supports it — the
        trainer's consumption pattern (device batches via the prefetcher,
        nothing retained host-side) satisfies the batch-lifetime contract
        unconditionally."""
        p = self.loader.params
        if (self.cfg.zero_copy and not p.zero_copy and p.fast_path
                and not p.use_processes
                and self.loader.dataset.supports_fast_path):
            self.loader.with_params(p.replace(zero_copy=True))

    # ---- main loop -----------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        cfg = self.cfg
        self._maybe_restore()
        self._apply_delivery_defaults()
        if cfg.autotune:
            self.tune_loader()
            if self.agent is None:
                self.online_tuner = self._make_online_tuner()
        if cfg.adaptive_locality:
            self.locality_controller = self._make_locality_controller()

        step = self.start_step
        batches = self._rebuild_stream(step)
        t_wall = time.perf_counter()
        last_metrics: Dict[str, Any] = {}
        while step < cfg.total_steps:
            self._maybe_rescale_lr()
            t0 = time.perf_counter()
            try:
                batch = next(batches)
            except StopIteration:
                batches = self._rebuild_stream(step)
                batch = next(batches)
            t_data = time.perf_counter() - t0
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.record(self.host_name, dt)
            step += 1

            # loader-drift retune (paper §5: cloud environments drift).
            # A triggered retune hot-swaps the live stream in place — no
            # rebuild, no lost batches, sampler position preserved.  In
            # fleet mode the same signal streams to the coordinator
            # instead (which may push a uniform retune or a reshard back).
            if self.agent is not None:
                self.agent.observe(data_s=t_data, step_s=dt)
            elif self.online_tuner is not None:
                self.online_tuner.observe(data_s=t_data, step_s=dt)
            if self.locality_controller is not None:
                self.locality_controller.step()

            if step % cfg.log_every == 0 or step == cfg.total_steps:
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "lr": float(metrics["lr"]),
                       "step_s": dt, "data_s": t_data}
                self.history.append(rec)
                last_metrics = rec
            if self.checkpointer and step % cfg.checkpoint_every == 0:
                self._save(step)
        self._save(cfg.total_steps, block=True)
        wall = time.perf_counter() - t_wall
        return {"final_step": step, "wall_s": wall, **last_metrics}
