"""AdamW + schedules, built from scratch (no optax in this environment —
and the assignment says build every substrate).

Optimizer state mirrors the parameter pytree, so the ZeRO-1 sharding story
is just "moments get the same logical axes as their parameter, plus the
data axis where divisible" — see ``distributed.sharding_rules`` and
``launch/dryrun.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray            # scalar int32
    mu: Any                      # first moment (pytree like params)
    nu: Any                      # second moment


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"     # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = jnp.ones_like(frac)
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * decay
    return cfg.peak_lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def init_adamw(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zeros2 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros2)


def abstract_adamw(abstract_params) -> AdamWState:
    """ShapeDtypeStruct state for the dry-run."""
    z = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    z2 = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=z, nu=z2)


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState,
                 *, grad_norm=None):
    """Returns (new_params, new_state, metrics).

    ``grad_norm``: precomputed global norm (the manual-DP step passes the
    psum'ed shard-exact norm; the pjit path computes it locally)."""
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if grad_norm is None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = grad_norm
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (delta + cfg.weight_decay
                                             * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
