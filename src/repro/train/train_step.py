"""Train-step builders: loss + grad + AdamW update as a single jit-able
function, with optional microbatching (gradient accumulation via lax.scan)
and int8 error-feedback gradient compression on the DP axes.

``make_train_step`` is what the dry-run lowers for every (arch x train
shape) cell and what the Trainer executes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import grad_compress
from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                   init_adamw)


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    remat_policy: str = "dots"         # none | dots | nothing | full
    microbatches: int = 1              # gradient accumulation steps
    compress_grads: bool = False       # int8 EF-compression of DP psum
    dp_manual: bool = False            # shard_map over the batch axes (see
                                       # distributed/dp_shard.py); falls back
                                       # to the pjit path off-mesh
    optimizer: AdamWConfig = AdamWConfig()


class TrainState:
    """Lightweight pytree container (registered below)."""

    def __init__(self, params, opt: AdamWState, err=None):
        self.params = params
        self.opt = opt
        self.err = err

    def tree_flatten(self):
        return (self.params, self.opt, self.err), None

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, lambda s: s.tree_flatten(),
    lambda aux, ch: TrainState.tree_unflatten(aux, ch))


def init_train_state(model, rng, cfg: TrainStepConfig) -> TrainState:
    params = model.init(rng)
    err = grad_compress.init_error_feedback(params) if cfg.compress_grads \
        else None
    return TrainState(params, init_adamw(params), err)


def abstract_train_state(model, cfg: TrainStepConfig) -> TrainState:
    from repro.train.optimizer import abstract_adamw
    params = model.abstract_params()
    err = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params) \
        if cfg.compress_grads else None
    return TrainState(params, abstract_adamw(params), err)


def _split_microbatches(batch, n: int):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def _make_manual_dp_step(model, cfg: TrainStepConfig, ctx, manual):
    """Train step with EXPLICIT data parallelism (distributed/dp_shard.py).

    The whole (microbatch-scan + optimizer) step runs inside shard_map over
    the batch axes ('pod','data'); the model axis stays auto (GSPMD TP).
    Gains over the pjit path (EXPERIMENTS.md §Perf):
      * FSDP weight gathers happen in bf16 (wire bytes halved vs the f32
        gathers GSPMD emitted) and their transpose is a bf16 reduce-scatter
        — the minimal per-microbatch communication;
      * every non-FSDP gradient is accumulated locally across microbatches
        and psum'ed ONCE per step instead of all-reduced per microbatch;
      * the optimizer updates shards in place (ZeRO-1: moments live on the
        same shards);
      * the vocab-sharded fused cross-entropy and the expert-parallel MoE
        dispatch (models/layers.py) both require the batch axes manual.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import dp_shard

    mesh = ctx.mesh
    manual = tuple(manual)
    R = dp_shard.manual_size(mesh)
    axes_tree = model.logical_axes()
    abs_tree = model.abstract_params()
    p_specs = dp_shard.param_manual_specs(ctx, axes_tree, abs_tree, manual)
    opt_specs = AdamWState(step=P(), mu=p_specs, nu=p_specs)
    bspec = P(manual if len(manual) > 1 else manual[0])

    # per-leaf replication factor over the manual axes (for the global
    # grad-norm: sharded leaves' local sq-sums add up exactly; replicated
    # leaves are over-counted by their replication factor).
    def _rep(ax):
        dims = dp_shard.rule_manual_dims(ctx, ax, manual)
        used = set(a for axes in dims.values() for a in axes)
        rep = 1
        for a in manual:
            if a not in used:
                rep *= mesh.shape[a]
        return float(rep)

    rep_tree = jax.tree_util.tree_map(_rep, axes_tree,
                                      is_leaf=dp_shard._is_axes_leaf)

    def dp_body(params, opt, batch):
        # microbatches split the LOCAL batch (per-device memory is what they
        # bound); clamp when the per-rank batch is smaller than requested.
        local_b = jax.tree_util.tree_leaves(batch)[0].shape[0]
        n_mb = max(1, min(cfg.microbatches, local_b))
        with ctx.manual_region(set(manual)):
            def loss_fn(p, mb):
                # non-stacked leaves gathered here (inside grad, so the
                # transpose reduce-scatters); stacked leaves per layer
                # inside the scan (stack.run_stack's dp hook).
                p_g = dp_shard.gather_params(p, axes_tree)
                loss, metrics = model.loss(p_g, mb,
                                           remat_policy=cfg.remat_policy)
                return loss, metrics

            grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

            if n_mb <= 1:
                (loss, metrics), grads = grad_fn(params, batch)
            else:
                mbs = _split_microbatches(batch, n_mb)

                def body(acc, mb):
                    (l, m), g = grad_fn(params, mb)
                    return jax.tree_util.tree_map(jnp.add, acc, g), (l, m)

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, (losses, metrics) = jax.lax.scan(body, zeros, mbs)
                loss = jnp.mean(losses)
                metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m),
                                                 metrics)

            # deferred DP sync: one psum per step; scale = mean over
            # (ranks x microbatches) of per-microbatch mean-loss grads.
            grads = dp_shard.deferred_psum(grads, axes_tree, ctx, manual,
                                           1.0 / (R * n_mb))
            loss = jax.lax.psum(loss, manual) / R
            metrics = jax.tree_util.tree_map(
                lambda m: jax.lax.psum(m, manual) / R, metrics)

            # exact global grad norm from shard-local partials
            sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) / r
                     for g, r in zip(jax.tree_util.tree_leaves(grads),
                                     jax.tree_util.tree_leaves(rep_tree)))
            gnorm = jnp.sqrt(jax.lax.psum(sq, manual))

            params2, opt2, opt_metrics = adamw_update(
                cfg.optimizer, params, grads, opt, grad_norm=gnorm)
            metrics = dict(metrics)
            metrics.update(opt_metrics)
            metrics["loss"] = loss
            return params2, opt2, metrics

    def step(state: TrainState, batch):
        f = jax.shard_map(dp_body, mesh=mesh,
                          in_specs=(p_specs, opt_specs, bspec),
                          out_specs=(p_specs, opt_specs, P()),
                          axis_names=set(manual), check_vma=False)
        params2, opt2, metrics = f(state.params, state.opt, batch)
        return TrainState(params2, opt2, state.err), metrics

    return step


def make_train_step(model, cfg: TrainStepConfig):
    """Returns step(state, batch) -> (state, metrics)."""
    if cfg.dp_manual:
        from repro.distributed import dp_shard
        from repro.distributed.sharding_rules import current_ctx
        ctx = current_ctx()
        if ctx is not None:
            manual = dp_shard.manual_axes(ctx.mesh)
            if manual and dp_shard.validate_manual_divisibility(
                    ctx, model.logical_axes(), model.abstract_params(),
                    manual):
                return _make_manual_dp_step(model, cfg, ctx, manual)

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb, remat_policy=cfg.remat_policy)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if cfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        mbs = _split_microbatches(batch, cfg.microbatches)

        def body(carry, mb):
            acc, _ = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            return (acc, loss), metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, loss), metrics = jax.lax.scan(body, (zeros, jnp.zeros(())), mbs)
        grads = jax.tree_util.tree_map(
            lambda g: g / cfg.microbatches, acc)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, Any]]:
        loss, metrics, grads = compute_grads(state.params, batch)
        err = state.err
        if cfg.compress_grads:
            # DP gradient sync passes through the int8 EF channel.  Under
            # pjit the psum is implicit in the sharding; the lossy channel
            # is applied explicitly so the wire payload is 8-bit.
            grads, err = grad_compress.compress_tree(grads, err)
        params, opt, opt_metrics = adamw_update(cfg.optimizer, state.params,
                                                grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params, opt, err), metrics

    return step
