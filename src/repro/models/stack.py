"""Unified decoder/encoder block stack for all families.

One block definition covers dense / moe / ssm / hybrid / encdec / vlm; the
layer stack is ``lax.scan`` over stacked params so compile time and HLO size
are independent of depth (60-88 layer configs lower as one block).  Remat
policy is applied to the scan body for training.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding_rules import constrain
from repro.models import layers as ll
from repro.models import ssm as ssm_mod
from repro.models.module import spec, stack_specs


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------
def block_specs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, Any]:
    if cfg.family == "ssm":
        return {"ln1": ll.norm_specs(cfg), "ssm": ssm_mod.ssm_specs(cfg)}
    p = {"ln1": ll.norm_specs(cfg), "attn": ll.attention_specs(cfg),
         "ln2": ll.norm_specs(cfg)}
    if cross:
        p["ln_cross"] = ll.norm_specs(cfg)
        p["cross"] = ll.attention_specs(cfg, cross=True)
    if cfg.family == "moe":
        p["moe"] = ll.moe_specs(cfg)
    else:
        p["mlp"] = ll.mlp_specs(cfg)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_mod.ssm_specs(cfg)
        p["mix_norm_attn"] = ll.rmsnorm_specs(cfg.d_model)
        p["mix_norm_ssm"] = ll.rmsnorm_specs(cfg.d_model)
    return p


def stack_param_specs(cfg: ModelConfig, num_layers: Optional[int] = None,
                      cross: bool = False):
    n = num_layers if num_layers is not None else cfg.num_layers
    return stack_specs(block_specs(cfg, cross=cross), n)


def _use_rope(cfg: ModelConfig) -> bool:
    return cfg.family != "encdec"


def manual_layer_hook(cfg: ModelConfig, *, cross: bool = False):
    """Per-layer FSDP gather hook (bf16) for any scan over stacked layer
    params — run_stack, decode, and the K/V-collection scans.  Returns None
    outside a manual region (pure pjit / single device)."""
    from repro.distributed import dp_shard
    from repro.distributed.sharding_rules import current_ctx
    from repro.models.module import logical_axes
    ctx = current_ctx()
    if ctx is None or not ctx.manual:
        return None
    return dp_shard.layer_hook(logical_axes(block_specs(cfg, cross=cross)))


def _global_flags(cfg: ModelConfig) -> np.ndarray:
    flags = np.zeros(cfg.num_layers, dtype=bool)
    for i in cfg.global_attn_layers:
        flags[i] = True
    return flags


# --------------------------------------------------------------------------
# full-sequence block (train / prefill / encoder)
# --------------------------------------------------------------------------
RES_AXES = ("batch", "seq", "embed_act")
RES_AXES_SP = ("batch", "seq_res", "embed_act")


def _attn_branch(p, cfg, h, positions, is_global, causal, res_axes=RES_AXES):
    rope = _use_rope(cfg)
    if cfg.global_attn_layers and cfg.sliding_window:
        full = functools.partial(ll.attention, p["attn"], cfg, causal=causal,
                                 window=0, num_sink=0, rope=rope,
                                 out_axes=res_axes)
        win = functools.partial(ll.attention, p["attn"], cfg, causal=causal,
                                window=cfg.sliding_window,
                                num_sink=cfg.num_meta_tokens, rope=rope,
                                out_axes=res_axes)
        return jax.lax.cond(is_global,
                            lambda hh, pp: full(hh, positions=pp),
                            lambda hh, pp: win(hh, positions=pp),
                            h, positions)
    return ll.attention(p["attn"], cfg, h, positions=positions, causal=causal,
                        window=cfg.sliding_window,
                        num_sink=cfg.num_meta_tokens if cfg.sliding_window else 0,
                        rope=rope, out_axes=res_axes)


def block(p, cfg: ModelConfig, x, *, positions, is_global, causal=True,
          enc_out=None, ssm_state_out: bool = False, sp: bool = False):
    """One layer.  Returns (x, aux_loss[, ssm_cache]).

    ``sp``: manual sequence parallelism — the residual stream x is sharded
    on the model axis along seq (norms/adds run on 1/16th of the tokens and
    the saved activation stack shrinks 16x); attention/MLP/MoE inputs are
    all-gathered and their outputs reduce-scattered (AG+RS = half the wire
    bytes of the all-reduce they replace)."""
    aux = jnp.zeros((), jnp.float32)
    res_axes = RES_AXES_SP if sp else RES_AXES
    h = ll.norm(p["ln1"], x, cfg)
    if sp:
        h = constrain(h, *RES_AXES)         # all-gather for full-seq attn
    ssm_cache = None
    if cfg.family == "ssm":
        if ssm_state_out:
            y, ssm_cache = ssm_mod.ssm(p["ssm"], cfg, h, return_state=True)
        else:
            y = ssm_mod.ssm(p["ssm"], cfg, h)
        x = x + y
        return (x, aux, ssm_cache) if ssm_state_out else (x, aux)

    attn_y = _attn_branch(p, cfg, h, positions, is_global, causal, res_axes)
    if cfg.family == "hybrid":
        if ssm_state_out:
            ssm_y, ssm_cache = ssm_mod.ssm(p["ssm"], cfg, h, return_state=True)
        else:
            ssm_y = ssm_mod.ssm(p["ssm"], cfg, h)
        mixed = 0.5 * (ll.rmsnorm(p["mix_norm_attn"], attn_y, cfg.norm_eps)
                       + ll.rmsnorm(p["mix_norm_ssm"], ssm_y, cfg.norm_eps))
        x = x + mixed
    else:
        x = x + attn_y

    if enc_out is not None and "cross" in p:
        hc = ll.norm(p["ln_cross"], x, cfg)
        if sp:
            hc = constrain(hc, *RES_AXES)
        x = x + ll.attention(p["cross"], cfg, hc, positions=positions,
                             causal=False, kv_x=enc_out, rope=False,
                             out_axes=res_axes)

    h2 = ll.norm(p["ln2"], x, cfg)
    if sp:
        h2 = constrain(h2, *RES_AXES)
    if cfg.family == "moe":
        y, aux_moe = ll.moe(p["moe"], cfg, h2, out_axes=res_axes)
        aux = aux + aux_moe
    else:
        y = ll.mlp(p["mlp"], cfg, h2, out_axes=res_axes)
    x = x + y
    return (x, aux, ssm_cache) if ssm_state_out else (x, aux)


def run_stack(params, cfg: ModelConfig, x, *, positions, causal=True,
              enc_out=None, num_layers: Optional[int] = None,
              remat_policy: str = "none", collect_ssm_state: bool = False):
    """Scan the block over stacked params.

    Inside a manual-DP region (train step wrapped in shard_map over the
    batch axes — distributed/dp_shard.py) each scanned layer slice passes
    through a per-layer FSDP gather hook: data-sharded weight dims are
    all-gathered in bf16 right before use and the gather's transpose
    reduce-scatters the bf16 grads — ZeRO-3 with minimal explicit traffic.

    Returns (x, aux) or (x, aux, ssm_caches) when collect_ssm_state."""
    from repro.distributed import dp_shard
    from repro.distributed.sharding_rules import current_ctx
    from repro.models.module import logical_axes

    n = num_layers if num_layers is not None else cfg.num_layers
    flags = jnp.asarray(_global_flags(cfg)[:n]) if cfg.global_attn_layers \
        else jnp.zeros(n, bool)

    ctx = current_ctx()
    cross = isinstance(params, dict) and "cross" in params
    param_hook = manual_layer_hook(cfg, cross=cross)
    sp = False
    if ctx is not None and ctx.manual:
        # manual sequence parallelism for attention-family residual streams
        # (SSM/hybrid scans need the full sequence; prefix tokens would
        # misalign the shard boundaries).
        sp = (cfg.uses_attention and not cfg.ssm_state_dim
              and cfg.num_meta_tokens == 0 and cfg.num_patches == 0
              and not collect_ssm_state
              and bool(ctx.mesh_axes_for("seq_res"))
              and x.shape[1] % ctx.mesh.shape["model"] == 0)
    if sp:
        x = constrain(x, *RES_AXES_SP)

    def body(carry, xs):
        xc, aux = carry
        p_layer, glob = xs
        if param_hook is not None:
            p_layer = param_hook(p_layer)
        if collect_ssm_state:
            xc, aux_l, ssm_cache = block(
                p_layer, cfg, xc, positions=positions, is_global=glob,
                causal=causal, enc_out=enc_out, ssm_state_out=True)
            return (xc, aux + aux_l), ssm_cache
        xc, aux_l = block(p_layer, cfg, xc, positions=positions,
                          is_global=glob, causal=causal, enc_out=enc_out,
                          sp=sp)
        return (xc, aux + aux_l), None

    if remat_policy != "none":
        policy = {
            "full": None,
            "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            "nothing": jax.checkpoint_policies.nothing_saveable,
        }[remat_policy]
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    (x, aux), ssm_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        (params, flags))
    if collect_ssm_state:
        return x, aux, ssm_caches
    return x, aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                 *, ring: bool, kv_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Shapes/dtypes for the stacked decode cache (leading dim = layers).

    ``kv_dtype``: bf16 default; fp8 (float8_e4m3fn) halves cache HBM for
    MHA archs whose 32k caches exceed the 16 GB budget (production KV-cache
    quantization; reads upcast to fp32 inside attention)."""
    L = cfg.num_layers
    out: Dict[str, Any] = {}
    if cfg.uses_attention:
        T = min(max_len, cfg.sliding_window) if ring else max_len
        kvshape = (L, batch, T, cfg.num_kv_heads, cfg.head_dim)
        out["k"] = (kvshape, kv_dtype)
        out["v"] = (kvshape, kv_dtype)
    if cfg.ssm_state_dim:
        shapes = ssm_mod.ssm_cache_shapes(cfg, batch)
        out["ssm_conv"] = ((L,) + shapes["conv"][0], shapes["conv"][1])
        out["ssm_state"] = ((L,) + shapes["state"][0], shapes["state"][1])
    if cfg.encoder_layers:
        enc_kv = (L, batch, cfg.max_source_positions, cfg.num_kv_heads,
                  cfg.head_dim)
        out["cross_k"] = (enc_kv, kv_dtype)
        out["cross_v"] = (enc_kv, kv_dtype)
    return out


def use_ring_cache(cfg: ModelConfig) -> bool:
    return (cfg.sliding_window > 0 and not cfg.global_attn_layers
            and cfg.num_meta_tokens == 0)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, abstract=False,
               kv_dtype=jnp.bfloat16):
    ring = use_ring_cache(cfg)
    shapes = cache_shapes(cfg, batch, max_len, ring=ring, kv_dtype=kv_dtype)
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def decode_block(p, cfg: ModelConfig, x, cache_layer, *, positions,
                 is_global, ring: bool):
    """One decode layer.  cache_layer: per-layer slice of the stacked cache."""
    aux = jnp.zeros((), jnp.float32)
    h = ll.norm(p["ln1"], x, cfg)
    new_cache = dict(cache_layer)

    if cfg.family == "ssm":
        y, ssm_c = ssm_mod.ssm_decode(
            p["ssm"], cfg, h,
            {"conv": cache_layer["ssm_conv"], "state": cache_layer["ssm_state"]})
        new_cache["ssm_conv"], new_cache["ssm_state"] = ssm_c["conv"], ssm_c["state"]
        return x + y, new_cache, aux

    kv = {"k": cache_layer["k"], "v": cache_layer["v"]}
    rope = _use_rope(cfg)
    if cfg.global_attn_layers and cfg.sliding_window:
        def full_fn(hh):
            return ll.attention_decode(p["attn"], cfg, hh, kv,
                                       positions=positions, window=0,
                                       num_sink=0, rope=rope, ring=False)
        def win_fn(hh):
            return ll.attention_decode(p["attn"], cfg, hh, kv,
                                       positions=positions,
                                       window=cfg.sliding_window,
                                       num_sink=cfg.num_meta_tokens,
                                       rope=rope, ring=False)
        attn_y, new_kv = jax.lax.cond(is_global, full_fn, win_fn, h)
    else:
        attn_y, new_kv = ll.attention_decode(
            p["attn"], cfg, h, kv, positions=positions,
            window=cfg.sliding_window,
            num_sink=cfg.num_meta_tokens if cfg.sliding_window else 0,
            rope=rope, ring=ring)
    new_cache["k"], new_cache["v"] = new_kv["k"], new_kv["v"]

    if cfg.family == "hybrid":
        y_ssm, ssm_c = ssm_mod.ssm_decode(
            p["ssm"], cfg, h,
            {"conv": cache_layer["ssm_conv"], "state": cache_layer["ssm_state"]})
        new_cache["ssm_conv"], new_cache["ssm_state"] = ssm_c["conv"], ssm_c["state"]
        mixed = 0.5 * (ll.rmsnorm(p["mix_norm_attn"], attn_y, cfg.norm_eps)
                       + ll.rmsnorm(p["mix_norm_ssm"], y_ssm, cfg.norm_eps))
        x = x + mixed
    else:
        x = x + attn_y

    if "cross" in p and "cross_k" in cache_layer:
        hc = ll.norm(p["ln_cross"], x, cfg)
        y, _ = ll.attention_decode(
            p["cross"], cfg, hc, {}, positions=positions, rope=False,
            cross_kv=(cache_layer["cross_k"], cache_layer["cross_v"]))
        x = x + y

    h2 = ll.norm(p["ln2"], x, cfg)
    if cfg.family == "moe":
        y, aux_moe = ll.moe(p["moe"], cfg, h2)
        aux = aux + aux_moe
    else:
        y = ll.mlp(p["mlp"], cfg, h2)
    return x + y, new_cache, aux


def run_stack_decode(params, cfg: ModelConfig, x, cache, *, positions):
    """Scan decode over layers; cache is scanned as xs and re-emitted as ys."""
    n = cfg.num_layers
    ring = use_ring_cache(cfg)
    flags = jnp.asarray(_global_flags(cfg)) if cfg.global_attn_layers \
        else jnp.zeros(n, bool)

    param_hook = manual_layer_hook(cfg, cross="cross" in params)

    def body(carry, xs):
        xc = carry
        p_layer, glob, cache_layer = xs
        if param_hook is not None:
            p_layer = param_hook(p_layer)
        xc, new_cache, _aux = decode_block(p_layer, cfg, xc, cache_layer,
                                           positions=positions, is_global=glob,
                                           ring=ring)
        return xc, new_cache

    x, new_cache = jax.lax.scan(body, x, (params, flags, cache))
    return x, new_cache
