"""Parameter-spec based module system.

Models declare their parameters as pytrees of ``ParamSpec`` (shape, dtype,
logical axes, initializer).  From one spec tree we derive:

* real parameters (``init_params`` — smoke tests / examples),
* abstract parameters (``abstract_params`` — the multi-pod dry-run lowers
  against ``ShapeDtypeStruct`` so nothing is ever allocated),
* sharding trees (``repro.distributed.sharding_rules`` maps logical axes to
  mesh axes).

This keeps "what the parameter is" and "how it is sharded" in one place,
which is what makes 40 (arch x shape) dry-run cells tractable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim
    dtype: Any = jnp.float32
    init: str = "normal"                     # normal | zeros | ones | embed
    scale: Optional[float] = None            # stddev override
    fan_in_dims: Tuple[int, ...] = (0,)      # dims treated as fan-in for scale

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"ParamSpec rank mismatch: shape {self.shape} vs axes {self.axes}")

    @property
    def fan_in(self) -> int:
        return int(np.prod([self.shape[d] for d in self.fan_in_dims])) or 1


def spec(shape, axes, init="normal", scale=None, dtype=jnp.float32,
         fan_in_dims=(0,)) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, init, scale, fan_in_dims)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(key, s: ParamSpec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    std = s.scale if s.scale is not None else 1.0 / np.sqrt(s.fan_in)
    if s.init == "embed":
        std = s.scale if s.scale is not None else 1.0
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)


def init_params(specs, rng):
    """Materialize real parameters from a spec tree (smoke scale only)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(specs):
    """ShapeDtypeStruct tree — no allocation; used by the dry-run."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec)


def logical_axes(specs):
    """Tree of logical-axis tuples, parallel to the param tree."""
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=is_spec)


def stacked(s: ParamSpec, num_layers: int) -> ParamSpec:
    """Stack a per-layer spec along a leading 'layers' (scan) axis."""
    return ParamSpec((num_layers,) + s.shape, ("layers",) + s.axes, s.dtype,
                     s.init, s.scale, tuple(d + 1 for d in s.fan_in_dims))


def stack_specs(tree, num_layers: int):
    return jax.tree_util.tree_map(lambda s: stacked(s, num_layers), tree,
                                  is_leaf=is_spec)
