from repro.models.lm import DecoderLM, EncDecLM, build_model  # noqa: F401
