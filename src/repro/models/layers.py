"""Layer library shared by all 10 architectures.

Conventions:
* params are pytrees of fp32 arrays; compute casts to ``COMPUTE_DTYPE``
  (bf16) at use-sites — fp32 master weights, bf16 math (TPU MXU native).
* projections keep *flattened* feature dims — q: (D, H*hd) — because every
  assigned arch has H*hd and K*hd divisible by the 16-way model axis even
  when H itself is not (yi-34b: 56 heads).  Reshape to heads happens after
  the sharding-constrained matmul.
* every activation passes through ``constrain`` with logical axes so the
  same model code lowers correctly on 1 CPU device and on the 512-chip mesh.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding_rules import constrain
from repro.kernels import ops
from repro.models.module import spec

COMPUTE_DTYPE = jnp.dtype(os.environ.get("REPRO_COMPUTE_DTYPE", "bfloat16"))


def cast(x):
    return x.astype(COMPUTE_DTYPE)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rmsnorm_specs(d: int):
    return {"scale": spec((d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float):
    return ops.rmsnorm(x, p["scale"], eps=eps)


def layernorm_specs(d: int):
    return {"scale": spec((d,), ("embed",), init="ones"),
            "bias": spec((d,), ("embed",), init="zeros")}


def layernorm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def norm_specs(cfg: ModelConfig):
    return layernorm_specs(cfg.d_model) if cfg.family == "encdec" \
        else rmsnorm_specs(cfg.d_model)


def norm(p, x, cfg: ModelConfig):
    return layernorm(p, x, cfg.norm_eps) if "bias" in p \
        else rmsnorm(p, x, cfg.norm_eps)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------
def rotary(x, positions, theta: float):
    """x: (B,S,H,D) (D even); positions: (B,S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def attention_specs(cfg: ModelConfig, cross: bool = False):
    d, nq = cfg.d_model, cfg.num_heads * cfg.head_dim
    nkv = cfg.num_kv_heads * cfg.head_dim
    p = {
        "wq": spec((d, nq), ("embed", "heads")),
        "wk": spec((d, nkv), ("embed", "kv_heads")),
        "wv": spec((d, nkv), ("embed", "kv_heads")),
        "wo": spec((nq, d), ("heads", "embed")),
    }
    if cfg.attn_bias:
        p["bq"] = spec((nq,), ("heads",), init="zeros")
        p["bk"] = spec((nkv,), ("kv_heads",), init="zeros")
        p["bv"] = spec((nkv,), ("kv_heads",), init="zeros")
    if cfg.qk_norm and not cross:
        p["q_norm"] = spec((cfg.head_dim,), (None,), init="ones")
        p["k_norm"] = spec((cfg.head_dim,), (None,), init="ones")
    return p


def _heads_shards() -> int:
    """Number of shards the heads_act rule would apply (1 outside a mesh)."""
    from repro.distributed.sharding_rules import current_ctx
    ctx = current_ctx()
    if ctx is None:
        return 1
    n = 1
    for a in ctx.mesh_axes_for("heads_act"):
        n *= ctx.mesh.shape[a]
    return n


def _pad_plan(num_heads: int, num_kv: int, shards: int):
    """Smallest (K2, G2) with K2 >= K, G2 >= G and K2*G2 % shards == 0.

    Sharding attention by heads requires head count divisible by the model
    axis; five assigned archs (yi 56H, qwen2 14H, whisper 20H, granite 24H,
    hymba 25H) are not.  Padding GQA groups (and kv heads when needed) costs
    (K2*G2/H - 1) extra attention flops — always far below the 16x waste of
    replicating attention over the model axis, and it keeps the parameter
    layout unchanged (activations are padded, not weights)."""
    if shards <= 1 or num_heads % shards == 0:
        return None
    g = num_heads // num_kv
    best = None
    for k2 in range(num_kv, num_kv + shards + 1):
        for g2 in range(g, g + shards + 1):
            if (k2 * g2) % shards == 0:
                if best is None or k2 * g2 < best[0] * best[1]:
                    best = (k2, g2)
    return best


def _pad_attention_heads(q, k, v, cfg: ModelConfig, plan):
    K2, G2 = plan
    B, S, H, D = q.shape
    K = cfg.num_kv_heads
    G = H // K
    q = q.reshape(B, S, K, G, D)
    q = jnp.pad(q, ((0, 0), (0, 0), (0, K2 - K), (0, G2 - G), (0, 0)))
    q = q.reshape(B, S, K2 * G2, D)
    if K2 != K:
        pad = ((0, 0), (0, 0), (0, K2 - K), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    q = constrain(q, "batch", "seq", "heads_act", None)
    k = constrain(k, "batch", "kv_seq", "kv_heads_act", None)
    v = constrain(v, "batch", "kv_seq", "kv_heads_act", None)
    return q, k, v


def _unpad_attention_heads(out, cfg: ModelConfig, plan):
    K2, G2 = plan
    B, S, _, D = out.shape
    K = cfg.num_kv_heads
    G = cfg.num_heads // K
    out = out.reshape(B, S, K2, G2, D)[:, :, :K, :G]
    return out.reshape(B, S, cfg.num_heads, D)


def _project_qkv(p, cfg: ModelConfig, x, kv_x):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dn->bsn", cast(x), cast(p["wq"]))
    k = jnp.einsum("bsd,dn->bsn", cast(kv_x), cast(p["wk"]))
    v = jnp.einsum("bsd,dn->bsn", cast(kv_x), cast(p["wv"]))
    if "bq" in p:
        q = q + cast(p["bq"])
        k = k + cast(p["bk"])
        v = v + cast(p["bv"])
    # Constrain on the HEADS dim after the reshape, not the flat dim: a flat
    # constraint with H % axis != 0 makes GSPMD treat the reshape as a
    # partial contraction and all-reduce the attention logits per block
    # (observed: 235MB x 1536 all-reduces on qwen2).  When heads don't
    # divide the axis the full-seq path pads GQA groups (_pad_plan) instead.
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, k.shape[1], cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, v.shape[1], cfg.num_kv_heads, cfg.head_dim)
    if _pad_plan(cfg.num_heads, cfg.num_kv_heads, _heads_shards()) is None:
        q = constrain(q, "batch", "seq", "heads_act", None)
        k = constrain(k, "batch", "kv_seq", "kv_heads_act", None)
        v = constrain(v, "batch", "kv_seq", "kv_heads_act", None)
    if "q_norm" in p:
        q = ops.rmsnorm(q, p["q_norm"], eps=cfg.norm_eps)
        k = ops.rmsnorm(k, p["k_norm"], eps=cfg.norm_eps)
    return q, k, v


def attention(p, cfg: ModelConfig, x, *, positions=None, causal=True,
              window: int = 0, num_sink: int = 0, kv_x=None, rope=True,
              out_axes=("batch", "seq", "embed_act")):
    """Full-sequence attention (train / prefill / encoder / cross).

    ``out_axes``: logical sharding of the output — under manual sequence
    parallelism the residual stream is seq-sharded on the model axis, so the
    wo contraction's psum lowers to a reduce-scatter (half the wire bytes).
    """
    kv_x = x if kv_x is None else kv_x
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    if rope:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, positions, cfg.rope_theta)
    plan = _pad_plan(cfg.num_heads, cfg.num_kv_heads, _heads_shards())
    if plan is not None:
        q, k, v = _pad_attention_heads(q, k, v, cfg, plan)
    out = ops.attention(q, k, v, causal=causal, window=window,
                        num_sink=num_sink)
    if plan is not None:
        out = _unpad_attention_heads(out, cfg, plan)
    else:
        out = constrain(out, "batch", "seq", "heads_act", None)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    y = jnp.einsum("bsn,nd->bsd", out, cast(p["wo"]))
    return constrain(y, *out_axes)


def attention_decode(p, cfg: ModelConfig, x, kv_cache, *, positions,
                     window: int = 0, num_sink: int = 0, rope=True,
                     ring: bool = False, cross_kv=None):
    """Single-step decode.  x: (B,1,D); positions: (B,) absolute positions.

    kv_cache: {"k","v"}: (B,T,K,hd).  ``ring=True`` means the cache is a
    ring buffer of size T (== window, only valid when every layer is
    windowed); otherwise T is the full context and windowing is applied as a
    mask.  Returns (out, new_cache).
    """
    B = x.shape[0]
    if cross_kv is not None:
        q = jnp.einsum("bsd,dn->bsn", cast(x), cast(p["wq"]))
        if "bq" in p:
            q = q + cast(p["bq"])
        q = q.reshape(B, 1, cfg.num_heads, cfg.head_dim)
        q = constrain(q, "batch", "seq", "heads_act", None)
        k, v = cross_kv
        out = ops.attention(q, k, v, causal=False)
        out = constrain(out, "batch", "seq", "heads_act", None)
        out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
        y = jnp.einsum("bsn,nd->bsd", out, cast(p["wo"]))
        return constrain(y, "batch", "seq", "embed_act"), kv_cache

    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if rope:
        q = rotary(q, positions[:, None], cfg.rope_theta)
        k_new = rotary(k_new, positions[:, None], cfg.rope_theta)

    T = kv_cache["k"].shape[1]
    slot = positions % T if ring else positions
    bidx = jnp.arange(B)
    k_cache = kv_cache["k"].at[bidx, slot].set(
        k_new[:, 0].astype(kv_cache["k"].dtype))
    v_cache = kv_cache["v"].at[bidx, slot].set(
        v_new[:, 0].astype(kv_cache["v"].dtype))
    # decode caches shard the *sequence* dim on the model axis (always
    # divisible, unlike kv-head counts) -> flash-decoding style partial
    # softmax with a small cross-shard reduction.
    k_cache = constrain(k_cache, "batch", "kv_seq", None, None)
    v_cache = constrain(v_cache, "batch", "kv_seq", None, None)

    j = jnp.arange(T)[None, :]
    pos_b = positions[:, None]
    if ring:
        # absolute position held by each ring slot; unwritten slots land in
        # the future or negative -> masked via kv_pos rules.
        kv_pos = pos_b - ((pos_b - j) % T)
        kv_pos = jnp.where(kv_pos > pos_b, -(10 ** 9), kv_pos)
        kv_valid = None
    else:
        kv_pos = jnp.broadcast_to(j, (B, T))
        kv_valid = positions + 1

    out = ops.attention(q, k_cache, v_cache, causal=True,
                        q_pos=pos_b, kv_pos=kv_pos, kv_valid=kv_valid,
                        window=window, num_sink=num_sink)
    out = constrain(out, "batch", "seq", "heads_act", None)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    y = jnp.einsum("bsn,nd->bsd", out, cast(p["wo"]))
    y = constrain(y, "batch", "seq", "embed_act")
    return y, {"k": k_cache, "v": v_cache}


# --------------------------------------------------------------------------
# MLP (dense)
# --------------------------------------------------------------------------
def mlp_specs(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_activation == "gelu":
        return {
            "wi": spec((d, f), ("embed", "mlp")),
            "bi": spec((f,), ("mlp",), init="zeros"),
            "wo": spec((f, d), ("mlp", "embed")),
            "bo": spec((d,), ("embed",), init="zeros"),
        }
    return {
        "wi": spec((d, f), ("embed", "mlp")),
        "wg": spec((d, f), ("embed", "mlp")),
        "wo": spec((f, d), ("mlp", "embed")),
    }


def mlp(p, cfg: ModelConfig, x, *, out_axes=("batch", "seq", "embed_act")):
    if "bi" in p:
        h = jnp.einsum("bsd,df->bsf", cast(x), cast(p["wi"])) + cast(p["bi"])
        h = jax.nn.gelu(h)
        h = constrain(h, "batch", "seq", "mlp_act")
        y = jnp.einsum("bsf,fd->bsd", h, cast(p["wo"])) + cast(p["bo"])
    else:
        g = jnp.einsum("bsd,df->bsf", cast(x), cast(p["wg"]))
        h = jnp.einsum("bsd,df->bsf", cast(x), cast(p["wi"]))
        h = jax.nn.silu(g) * h
        h = constrain(h, "batch", "seq", "mlp_act")
        y = jnp.einsum("bsf,fd->bsd", h, cast(p["wo"]))
    return constrain(y, *out_axes)


# --------------------------------------------------------------------------
# MoE (top-k routing, expert-parallel dispatch)
# --------------------------------------------------------------------------
EP_DESIGN = 16   # production model-axis size; fixes the virtual layout


def _moe_parts(cfg: ModelConfig) -> int:
    """f-split factor of the virtual-expert layout.

    When E < EP_DESIGN (mixtral: 8 experts, 16-way axis) each expert is
    split into ``parts`` f-slices, giving V = E*parts virtual experts that
    shard cleanly on the model axis — EP x per-expert-TP hybrid with no
    weight replication and no idle ranks.  Mathematically identical to the
    unsplit expert (SwiGLU is elementwise in f; the wo contraction's f-sum
    becomes the EP combine psum)."""
    E, f = cfg.num_experts, cfg.expert_d_ff
    if 0 < E < EP_DESIGN and EP_DESIGN % E == 0:
        p = EP_DESIGN // E
        if f % p == 0:
            return p
    return 1


def moe_specs(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    parts = _moe_parts(cfg)
    p = {"router": spec((d, e), ("embed", "experts"), scale=0.02)}
    if parts > 1:
        # virtual TP-split layout: (V, d, f/parts), model-sharded on V.
        # wo keeps the LOGICAL fan-in f (not f/parts) for faithful init.
        v, fl = e * parts, f // parts
        p.update({
            "wi": spec((v, d, fl), ("experts_virt", "embed", None),
                       fan_in_dims=(1,)),
            "wg": spec((v, d, fl), ("experts_virt", "embed", None),
                       fan_in_dims=(1,)),
            "wo": spec((v, fl, d), ("experts_virt", None, "embed"),
                       scale=1.0 / float(np.sqrt(f))),
        })
    else:
        # E >= axis (granite: 40): weights replicated over the model axis
        # (small: d_ff=512) and sliced per-rank at dispatch; capacity-split
        # replicas keep every rank busy when E % axis != 0.
        p.update({
            "wi": spec((e, d, f), ("experts", "embed", None),
                       fan_in_dims=(1,)),
            "wg": spec((e, d, f), ("experts", "embed", None),
                       fan_in_dims=(1,)),
            "wo": spec((e, f, d), ("experts", None, "embed"),
                       fan_in_dims=(1,)),
        })
    return p


def _dense_expert_weights(p, cfg: ModelConfig):
    """Un-virtualize (V, d, f/parts) -> (E, d, f) for the reference path."""
    parts = _moe_parts(cfg)
    if parts == 1:
        return p["wi"], p["wg"], p["wo"]
    E, f = cfg.num_experts, cfg.expert_d_ff
    d, fl = cfg.d_model, f // parts
    wi = p["wi"].reshape(E, parts, d, fl).transpose(0, 2, 1, 3).reshape(E, d, f)
    wg = p["wg"].reshape(E, parts, d, fl).transpose(0, 2, 1, 3).reshape(E, d, f)
    wo = p["wo"].reshape(E, parts * fl, d)
    return wi, wg, wo


def _route(p, cfg: ModelConfig, xf):
    """Router: returns (top_g, top_e, aux_loss)."""
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = jnp.einsum("td,de->te", cast(xf), cast(p["router"]))
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T, E)
    top_g, top_e = jax.lax.top_k(gates, K)                        # (T, K)
    top_g = top_g / jnp.clip(top_g.sum(-1, keepdims=True), 1e-9)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)
    mean_gate = jnp.mean(gates, axis=0)
    aux = cfg.router_aux_loss * E * jnp.sum(density * mean_gate)
    return top_g, top_e, aux


def _sorted_assignments(top_g, top_e, T: int, E: int):
    """Sort (token, k) assignments by expert; returns (se, sg, st, pos_in_e)."""
    K = top_e.shape[1]
    flat_e = top_e.reshape(-1)
    flat_g = top_g.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, st = flat_e[order], flat_g[order], flat_t[order]
    same = jax.nn.one_hot(se, E, dtype=jnp.int32)                 # (TK, E)
    pos_in_e = (jnp.cumsum(same, axis=0) - same)[jnp.arange(se.shape[0]), se]
    return se, sg, st, pos_in_e


def _slot_tables(se, sg, st, pos_in_e, *, num_slots: int, cap: int,
                 slot_of, cap_pos):
    """Scatter sorted assignments into dense (num_slots*cap,) tables."""
    ids = jnp.where(cap_pos < cap, slot_of * cap + cap_pos,
                    num_slots * cap)                              # OOB -> drop
    tok = jnp.zeros((num_slots * cap,), jnp.int32).at[ids].set(st, mode="drop")
    gate = jnp.zeros((num_slots * cap,), jnp.float32).at[ids].set(
        sg, mode="drop")
    used = jnp.zeros((num_slots * cap,), jnp.float32).at[ids].set(
        1.0, mode="drop")
    return tok, gate, used


def _moe_reference(p, cfg: ModelConfig, x,
                   out_axes=("batch", "seq", "embed_act")):
    """Capacity-bounded gather dispatch on one logical device (smoke tests,
    serve cells; the oracle the EP path is tested against)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, D)
    top_g, top_e, aux = _route(p, cfg, xf)
    se, sg, st, pos_in_e = _sorted_assignments(top_g, top_e, T, E)
    C = max(min(int(np.ceil(T * K / E * cfg.capacity_factor)), T), 1)
    tok, gate, used = _slot_tables(se, sg, st, pos_in_e, num_slots=E, cap=C,
                                   slot_of=se, cap_pos=pos_in_e)

    wi, wg, wo = _dense_expert_weights(p, cfg)
    xe = cast(xf)[tok].reshape(E, C, D)
    xe = xe * used.reshape(E, C, 1).astype(xe.dtype)
    xe = constrain(xe, "experts_act", "moe_cap", "embed_act")
    g = jnp.einsum("ecd,edf->ecf", xe, cast(wg))
    h = jax.nn.silu(g) * jnp.einsum("ecd,edf->ecf", xe, cast(wi))
    h = constrain(h, "experts_act", "moe_cap", "mlp_act")
    ye = jnp.einsum("ecf,efd->ecd", h, cast(wo))
    ye_flat = ye.reshape(E * C, D) * (gate * used)[:, None].astype(ye.dtype)
    y = jnp.zeros((T, D), ye_flat.dtype).at[tok].add(ye_flat)
    return constrain(y.reshape(B, S, D), *out_axes), aux


def _ep_axes():
    from repro.distributed.sharding_rules import current_ctx
    ctx = current_ctx()
    if ctx is None:
        return None, ()
    return ctx, ctx.mesh_axes_for("experts_virt", include_manual=True)


def moe(p, cfg: ModelConfig, x, *, out_axes=("batch", "seq", "embed_act")):
    """Top-k MoE.  Inside a manual-DP region with a model axis this runs the
    expert-parallel path: routing and dispatch tables are computed per data
    shard (no cross-shard dispatch collectives — tokens are replicated over
    the model axis, so each EP rank locally selects the tokens routed to ITS
    experts), the expert FFN runs sharded over the model axis, and the only
    collective is the combine psum of the (T_local, D) output.  The v0
    dense-dispatch path all-gathered (E, C, D) buffers and all-reduced 8-16
    GB per layer (EXPERIMENTS.md §Perf granite iteration).

    Returns (y, aux_loss)."""
    ctx, ep_axes = _ep_axes()
    ep = 1
    for a in ep_axes:
        ep *= ctx.mesh.shape[a]
    # EP path requires the batch axes to be manual (train manual-DP / the
    # serve manual wrapper); otherwise x is still globally sharded and the
    # reference path's constraints handle it.
    batch_manual = ctx is not None and all(
        a in ctx.manual
        for a in ctx.mesh_axes_for("batch", include_manual=True))
    if (ctx is None or ep <= 1 or not batch_manual or len(ep_axes) != 1
            or os.environ.get("REPRO_MOE_EP", "1") == "0"):
        return _moe_reference(p, cfg, x, out_axes)

    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    parts = _moe_parts(cfg)
    T = B * S
    xf = x.reshape(T, D)
    top_g, top_e, aux = _route(p, cfg, xf)
    se, sg, st, pos_in_e = _sorted_assignments(top_g, top_e, T, E)

    if parts > 1:
        # E < axis: V = E*parts f-split virtual experts; every part of an
        # expert receives the SAME capacity slots (partial-f compute).
        V = E * parts
        C = max(min(int(np.ceil(T * K / E * cfg.capacity_factor)), T), 1)
        tok_e, gate_e, used_e = _slot_tables(
            se, sg, st, pos_in_e, num_slots=E, cap=C, slot_of=se,
            cap_pos=pos_in_e)
        tok = jnp.tile(tok_e.reshape(E, 1, C), (1, parts, 1)).reshape(-1)
        gate = jnp.tile(gate_e.reshape(E, 1, C), (1, parts, 1)).reshape(-1)
        used = jnp.tile(used_e.reshape(E, 1, C), (1, parts, 1)).reshape(-1)
    else:
        # E >= axis: V = round-up(E, ep) virtual slots, v -> expert v % E —
        # experts with two slots (capacity replicas) keep the padded ranks
        # busy; replicas share weights exactly (same slice), so the model is
        # unchanged.
        V = int(np.ceil(E / ep) * ep)
        C = max(int(np.ceil(T * K / V * cfg.capacity_factor)), 1)
        n_virt = (V - se - 1) // E + 1          # replicas of this expert
        replica = pos_in_e % n_virt
        cap_pos = pos_in_e // n_virt
        v_of = replica * E + se
        tok, gate, used = _slot_tables(se, sg, st, pos_in_e, num_slots=V,
                                       cap=C, slot_of=v_of, cap_pos=cap_pos)

    Vloc = V // ep
    axis = ep_axes[0]

    def body(xf, wi, wg, wo, tok, gate, used):
        r = jax.lax.axis_index(axis)
        if parts > 1:
            wi_l, wg_l, wo_l = wi, wg, wo       # already (Vloc, d, f/parts)
        else:
            idx = (r * Vloc + jnp.arange(Vloc)) % E
            wi_l, wg_l, wo_l = wi[idx], wg[idx], wo[idx]
        xe = cast(xf)[tok].reshape(Vloc, -1, D)
        xe = xe * used.reshape(Vloc, -1, 1).astype(xe.dtype)
        g = jnp.einsum("ecd,edf->ecf", xe, cast(wg_l))
        h = jax.nn.silu(g) * jnp.einsum("ecd,edf->ecf", xe, cast(wi_l))
        ye = jnp.einsum("ecf,efd->ecd", h, cast(wo_l))
        w8 = (gate * used).reshape(Vloc, -1, 1).astype(ye.dtype)
        y = jnp.zeros((T, D), ye.dtype).at[tok].add(
            (ye * w8).reshape(-1, D))
        return jax.lax.psum(y, axis)

    from jax.sharding import PartitionSpec as P
    w_spec = P(axis) if parts > 1 else P()
    y = jax.shard_map(
        body, in_specs=(P(), w_spec, w_spec, w_spec, P(axis), P(axis),
                        P(axis)),
        out_specs=P(), axis_names={axis}, check_vma=False)(
        xf, p["wi"], p["wg"], p["wo"], tok, gate, used)
    y = y.reshape(B, S, D)
    return constrain(y, *out_axes), aux


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------
def embed_specs(cfg: ModelConfig):
    p = {"tokens": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                        init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                            scale=0.02)
    return p


def embed(p, cfg: ModelConfig, tokens):
    # NOTE: no strong-typed scalar math here — an `x * np.sqrt(1.0)`
    # (np.float64) silently promoted the WHOLE residual stream to f32:
    # 2x the saved-activation HBM, 2x every residual psum (found via the
    # trip-weighted traffic profile, EXPERIMENTS.md §Perf iteration 3).
    x = cast(p["tokens"])[tokens]
    return constrain(x, "batch", "seq", "embed_act")


def unembed(p, cfg: ModelConfig, x):
    w = cast(p["tokens"]).T if cfg.tie_embeddings else cast(p["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", cast(x), w)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return constrain(logits, "batch", "seq", "vocab_act")


def unembed_xent(p, cfg: ModelConfig, x, targets, mask):
    """Vocab-sharded fused unembed + cross-entropy.

    The dense path materializes (B, S, V) f32 logits — 4.9 GB/device for
    qwen2's 152k vocab at one 4k microbatch — and the label gather over a
    model-sharded V triggers SPMD involuntary full rematerialization.  Here
    each model rank computes only its (B, S, V/16) logit slice; the
    cross-shard reduction is three (B, S) psums (max / sum-exp / gold).
    Falls back to the dense path off-mesh.  Returns (ce_sum, denom)."""
    from repro.distributed.sharding_rules import current_ctx
    ctx = current_ctx()
    axes = ctx.mesh_axes_for("vocab_act", include_manual=True) if ctx else ()
    axes = tuple(a for a in axes if a not in (ctx.manual if ctx else ()))
    n = 1
    for a in axes:
        n *= ctx.mesh.shape[a]
    batch_manual = ctx is not None and all(
        a in ctx.manual
        for a in ctx.mesh_axes_for("batch", include_manual=True))
    if ctx is None or n <= 1 or len(axes) != 1 or not batch_manual:
        logits = unembed(p, cfg, x)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   targets[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mask
        return ce.sum(), jnp.maximum(mask.sum(), 1.0)

    axis = axes[0]
    V = cfg.vocab_size
    Vp = int(np.ceil(V / n) * n)
    w = cast(p["tokens"]) if cfg.tie_embeddings else cast(p["unembed"]).T
    if Vp != V:
        w = jnp.pad(w, ((0, Vp - V), (0, 0)))           # (Vp, D) row-padded
    Vloc = Vp // n
    softcap = cfg.logit_softcap

    def body(x, w_loc, targets, mask):
        r = jax.lax.axis_index(axis)
        off = r * Vloc
        logits = jnp.einsum("bsd,vd->bsv", cast(x), w_loc).astype(jnp.float32)
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        # mask padded vocab rows
        j = off + jnp.arange(Vloc)
        logits = jnp.where(j[None, None, :] < V, logits, -1e30)
        # stop_gradient is exact here (dLSE/dm = 0 analytically) and keeps
        # pmax out of the backward graph (no pmax differentiation rule).
        m_loc = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
        m = jax.lax.pmax(m_loc, axis)
        se = jax.lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                          axis)
        lse = m + jnp.log(se)
        t_loc = jnp.clip(targets - off, 0, Vloc - 1)
        in_range = (targets >= off) & (targets < off + Vloc)
        gold_loc = jnp.take_along_axis(logits, t_loc[..., None],
                                       axis=-1)[..., 0]
        gold = jax.lax.psum(jnp.where(in_range, gold_loc, 0.0), axis)
        ce = (lse - gold) * mask
        return ce.sum(), jnp.maximum(mask.sum(), 1.0)

    from jax.sharding import PartitionSpec as P
    kw = {} if (ctx.manual) else {"mesh": ctx.mesh}
    ce_sum, denom = jax.shard_map(
        body, in_specs=(P(), P(axis, None), P(), P()),
        out_specs=(P(), P()), axis_names={axis}, check_vma=False, **kw)(
        x, w, targets, mask)
    return ce_sum, denom
