"""Mamba2 (SSD) mixer: projections + causal depthwise conv + chunked SSD
scan, with a single-token recurrent path for decode.

Shapes follow the Mamba2 paper: inner width din = expand*d_model, nh =
din/head_dim SSD heads, state (nh, head_dim, N) per sequence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding_rules import constrain
from repro.kernels import ops
from repro.models.layers import cast
from repro.models.module import spec


def ssm_specs(cfg: ModelConfig):
    d, din = cfg.d_model, cfg.d_inner
    g, n, nh = cfg.ssm_num_groups, cfg.ssm_state_dim, cfg.ssm_num_heads
    w = cfg.ssm_conv_width
    conv_dim = din + 2 * g * n
    return {
        "in_x": spec((d, din), ("embed", "ssm_inner")),
        "in_z": spec((d, din), ("embed", "ssm_inner")),
        "in_B": spec((d, g * n), ("embed", "ssm_state")),
        "in_C": spec((d, g * n), ("embed", "ssm_state")),
        "in_dt": spec((d, nh), ("embed", "ssm_heads")),
        "dt_bias": spec((nh,), ("ssm_heads",), init="zeros"),
        "A_log": spec((nh,), ("ssm_heads",), init="zeros"),
        "D": spec((nh,), ("ssm_heads",), init="ones"),
        "conv_w": spec((w, conv_dim), (None, "ssm_inner"), scale=0.5,
                       fan_in_dims=(0,)),
        "conv_b": spec((conv_dim,), ("ssm_inner",), init="zeros"),
        "gate_norm": spec((din,), ("ssm_inner",), init="ones"),
        "out": spec((din, d), ("ssm_inner", "embed")),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv1d.  u: (B,S,C); w: (W,C); b: (C,)."""
    W = w.shape[0]
    out = u * cast(w[-1])
    for i in range(1, W):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :u.shape[1]]
        out = out + shifted * cast(w[-1 - i])
    return out + cast(b)


def ssm_cache_shapes(cfg: ModelConfig, batch: int):
    """Per-layer decode state shapes (stacked over layers by the stack)."""
    din = cfg.d_inner
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim
    conv_dim = din + 2 * g * n
    return {
        "conv": ((batch, cfg.ssm_conv_width - 1, conv_dim), jnp.bfloat16),
        "state": ((batch, cfg.ssm_num_heads, cfg.ssm_head_dim, n), jnp.float32),
    }


def _project(p, cfg: ModelConfig, x):
    g, n = cfg.ssm_num_groups, cfg.ssm_state_dim
    xs = jnp.einsum("bsd,de->bse", cast(x), cast(p["in_x"]))
    z = jnp.einsum("bsd,de->bse", cast(x), cast(p["in_z"]))
    Bm = jnp.einsum("bsd,de->bse", cast(x), cast(p["in_B"]))
    Cm = jnp.einsum("bsd,de->bse", cast(x), cast(p["in_C"]))
    dt = jnp.einsum("bsd,dh->bsh", cast(x), cast(p["in_dt"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return xs, z, Bm, Cm, dt


def ssm(p, cfg: ModelConfig, x, *, return_state: bool = False):
    """Full-sequence SSD.  x: (B,S,D) -> (B,S,D)."""
    B, S, _ = x.shape
    g, n, nh, hd = (cfg.ssm_num_groups, cfg.ssm_state_dim, cfg.ssm_num_heads,
                    cfg.ssm_head_dim)
    xs, z, Bm, Cm, dt = _project(p, cfg, x)
    u_raw = jnp.concatenate([xs, Bm, Cm], axis=-1)
    u = jax.nn.silu(_causal_conv(u_raw, p["conv_w"], p["conv_b"]))
    u = constrain(u, "batch", "seq", "ssm_inner_act")
    xs, Bm, Cm = jnp.split(u, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)

    xh = xs.reshape(B, S, nh, hd)
    Bh = Bm.reshape(B, S, g, n)
    Ch = Cm.reshape(B, S, g, n)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if return_state:
        y, state = ops.ssd_prefill(xh, dt, A, Bh, Ch, chunk=cfg.ssm_chunk)
    else:
        y = ops.ssd(xh, dt, A, Bh, Ch, chunk=cfg.ssm_chunk)
        state = None
    y = y + xh * cast(p["D"])[None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner)
    y = y * jax.nn.silu(z)
    y = ops.rmsnorm(y, p["gate_norm"], eps=cfg.norm_eps)
    y = constrain(y, "batch", "seq", "ssm_inner_act")
    out = jnp.einsum("bse,ed->bsd", cast(y), cast(p["out"]))
    out = constrain(out, "batch", "seq", "embed_act")
    if return_state:
        w = cfg.ssm_conv_width
        conv_tail = u_raw[:, -(w - 1):].astype(jnp.bfloat16)
        return out, {"conv": conv_tail, "state": state}
    return out


def ssm_decode(p, cfg: ModelConfig, x, cache):
    """Single-token recurrence.  x: (B,1,D); cache from ssm_cache_shapes."""
    B = x.shape[0]
    g, n, nh, hd = (cfg.ssm_num_groups, cfg.ssm_state_dim, cfg.ssm_num_heads,
                    cfg.ssm_head_dim)
    xs, z, Bm, Cm, dt = _project(p, cfg, x)
    u_new = jnp.concatenate([xs, Bm, Cm], axis=-1)[:, 0]       # (B, conv_dim)

    conv_hist = cache["conv"]                                   # (B, W-1, C)
    u_win = jnp.concatenate([conv_hist.astype(u_new.dtype),
                             u_new[:, None]], axis=1)           # (B, W, C)
    w = cast(p["conv_w"])                                       # (W, C)
    conv_out = jnp.einsum("bwc,wc->bc", u_win, w) + cast(p["conv_b"])
    u = jax.nn.silu(conv_out)
    new_conv = u_win[:, 1:].astype(cache["conv"].dtype)

    xs1, Bm1, Cm1 = jnp.split(u, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    xh = xs1.reshape(B, nh, hd)
    Bh = Bm1.reshape(B, g, n)
    Ch = Cm1.reshape(B, g, n)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, new_state = ops.ssd_step(cache["state"], xh, dt[:, 0], A, Bh, Ch)
    y = y + xh * cast(p["D"])[None, :, None]
    y = y.reshape(B, 1, cfg.d_inner)
    y = y * jax.nn.silu(z)
    y = ops.rmsnorm(y, p["gate_norm"], eps=cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", cast(y), cast(p["out"]))
    out = constrain(out, "batch", "seq", "embed_act")
    return out, {"conv": new_conv, "state": new_state}
