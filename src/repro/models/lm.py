"""Model classes: DecoderLM (dense / moe / ssm / hybrid / vlm) and
EncDecLM (whisper).  These are what the launchers, trainer and serving
engine consume; each exposes spec trees, loss / prefill / decode functions
and ShapeDtypeStruct input specs per assigned shape cell.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding_rules import constrain
from repro.models import layers as ll
from repro.models import stack as stk
from repro.models.module import (abstract_params, init_params, logical_axes,
                                 spec)


def _sinusoidal(positions, d):
    """positions: (B,S) -> (B,S,d) fixed sinusoidal embedding."""
    half = d // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def cross_entropy(logits, targets, mask):
    """fp32 CE with z-loss-free logsumexp; mask: (B,S) {0,1}."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return ce.sum() / denom


class DecoderLM:
    """Decoder-only LM covering dense, moe, ssm, hybrid and vlm families."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- specs -----------------------------------------------------------
    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        p = {
            "embed": ll.embed_specs(cfg),
            "layers": stk.stack_param_specs(cfg),
            "final_norm": ll.norm_specs(cfg),
        }
        if cfg.num_meta_tokens:
            p["meta_tokens"] = spec((cfg.num_meta_tokens, cfg.d_model),
                                    (None, "embed"), scale=0.02)
        if cfg.num_patches:
            p["patch_proj"] = {
                "w": spec((cfg.patch_embed_dim, cfg.d_model),
                          (None, "embed")),
                "b": spec((cfg.d_model,), ("embed",), init="zeros"),
            }
        return p

    def init(self, rng):
        return init_params(self.param_specs(), rng)

    def abstract_params(self):
        return abstract_params(self.param_specs())

    def logical_axes(self):
        return logical_axes(self.param_specs())

    # ---- embedding composition --------------------------------------------
    def _compose_input(self, params, batch):
        """Embed tokens, prepend patch embeds (vlm) and meta tokens (hymba).

        Returns (x, positions, text_start)."""
        cfg = self.cfg
        x = ll.embed(params["embed"], cfg, batch["tokens"])
        B = x.shape[0]
        prefix = 0
        if cfg.num_patches and "patch_embeds" in batch:
            pe = jnp.einsum("bpk,kd->bpd", ll.cast(batch["patch_embeds"]),
                            ll.cast(params["patch_proj"]["w"]))
            pe = pe + ll.cast(params["patch_proj"]["b"])
            x = jnp.concatenate([pe, x], axis=1)
            prefix += cfg.num_patches
        if cfg.num_meta_tokens:
            meta = jnp.broadcast_to(
                ll.cast(params["meta_tokens"])[None],
                (B, cfg.num_meta_tokens, cfg.d_model))
            x = jnp.concatenate([meta, x], axis=1)
            prefix += cfg.num_meta_tokens
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = constrain(x, "batch", "seq", "embed_act")
        return x, positions, prefix

    # ---- train loss --------------------------------------------------------
    def loss(self, params, batch, *, remat_policy: str = "dots"):
        cfg = self.cfg
        x, positions, prefix = self._compose_input(params, batch)
        x, aux = stk.run_stack(params["layers"], cfg, x, positions=positions,
                               causal=True, remat_policy=remat_policy)
        x = ll.norm(params["final_norm"], x, cfg)
        if prefix:
            x = x[:, prefix:]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(batch["targets"].shape, jnp.float32)
        ce_sum, denom = ll.unembed_xent(params["embed"], cfg, x,
                                        batch["targets"], mask)
        loss = ce_sum / denom + aux
        metrics = {"loss": loss, "aux_loss": aux,
                   "tokens": mask.sum()}
        return loss, metrics

    # ---- inference ---------------------------------------------------------
    def prefill(self, params, batch, cache):
        """Run the prompt, fill the cache, return last-position logits."""
        cfg = self.cfg
        x, positions, prefix = self._compose_input(params, batch)
        B, S = x.shape[0], x.shape[1]

        if cfg.uses_attention:
            # run full-sequence attention while also materializing K/V into
            # the cache: recompute K/V per layer from the stack params.
            pass
        collect = bool(cfg.ssm_state_dim)
        out = stk.run_stack(params["layers"], cfg, x, positions=positions,
                            causal=True, remat_policy="none",
                            collect_ssm_state=collect)
        if collect:
            h, aux, ssm_caches = out
        else:
            h, aux = out
            ssm_caches = None
        h = ll.norm(params["final_norm"], h, cfg)
        logits = ll.unembed(params["embed"], cfg, h[:, -1:])

        new_cache = dict(cache)
        if cfg.uses_attention:
            # collect K/V already in the CACHE dtype: the (L,B,S,K,hd)
            # stack is cache-sized; stacking bf16/f32 then converting made
            # XLA materialize replicated f32 copies (100 GiB/dev on phi-3
            # prefill_32k).
            k, v = self._kv_for_prompt(params["layers"], x, positions,
                                       out_dtype=cache["k"].dtype)
            T = cache["k"].shape[2]
            write = min(S, T)
            # ring cache slots follow pos % T: keep the last T tokens and
            # roll them so token at absolute pos p lands in slot p % T.
            if stk.use_ring_cache(cfg) and S >= T:
                shift = (S - T) % T
                new_cache["k"] = jnp.roll(k[:, :, S - T:], shift, axis=2)
                new_cache["v"] = jnp.roll(v[:, :, S - T:], shift, axis=2)
            elif write == T and S == T:
                new_cache["k"], new_cache["v"] = k, v
            else:
                new_cache["k"] = jax.lax.dynamic_update_slice(
                    cache["k"], k[:, :, :write], (0, 0, 0, 0, 0))
                new_cache["v"] = jax.lax.dynamic_update_slice(
                    cache["v"], v[:, :, :write], (0, 0, 0, 0, 0))
        if ssm_caches is not None:
            new_cache["ssm_conv"] = ssm_caches["conv"].astype(
                cache["ssm_conv"].dtype)
            new_cache["ssm_state"] = ssm_caches["state"].astype(
                cache["ssm_state"].dtype)
        return logits, new_cache

    def _kv_for_prompt(self, stacked, x, positions, out_dtype=None):
        """K/V for every layer of a prompt, collected inside the layer scan.
        Returns a (L,B,S,K,hd) pair, seq-sharded like the cache and already
        in the cache dtype."""
        cfg = self.cfg
        hook = stk.manual_layer_hook(cfg)

        def body(carry, p_layer_and_flag):
            xc = carry
            p_layer, glob = p_layer_and_flag
            if hook is not None:
                p_layer = hook(p_layer)
            h = ll.norm(p_layer["ln1"], xc, cfg)
            q, k, v = ll._project_qkv(p_layer["attn"], cfg, h, h)
            if stk._use_rope(cfg):
                k = ll.rotary(k, positions, cfg.rope_theta)
            if out_dtype is not None:
                k, v = k.astype(out_dtype), v.astype(out_dtype)
            k = constrain(k, "batch", "kv_seq", "kv_heads_act", None)
            v = constrain(v, "batch", "kv_seq", "kv_heads_act", None)
            xc, _aux = stk.block(p_layer, cfg, xc, positions=positions,
                                 is_global=glob, causal=True)
            return xc, (k, v)

        flags = jnp.asarray(stk._global_flags(cfg)) if cfg.global_attn_layers \
            else jnp.zeros(cfg.num_layers, bool)
        _, (ks, vs) = jax.lax.scan(body, x, (stacked, flags))
        # constrain the STACKED result too: GSPMD back-propagates the
        # sharding into the scan's ys buffer (the per-iteration constraint
        # alone left the loop accumulator replicated over the model axis).
        ks = constrain(ks, "layers", "batch", "kv_seq", "kv_heads_act", None)
        vs = constrain(vs, "layers", "batch", "kv_seq", "kv_heads_act", None)
        return ks, vs

    @property
    def _prefix_len(self) -> int:
        """Internal tokens prepended to the text (meta tokens + patches)."""
        return self.cfg.num_meta_tokens + self.cfg.num_patches

    def init_cache(self, batch: int, max_len: int, abstract: bool = False,
                   kv_dtype=None):
        cfg = self.cfg
        internal = max_len + self._prefix_len
        import jax.numpy as _jnp
        return stk.init_cache(cfg, batch, internal, abstract=abstract,
                              kv_dtype=kv_dtype or _jnp.bfloat16)

    def decode_step(self, params, cache, tokens, positions):
        """tokens: (B,1); positions: (B,) text positions (the model offsets
        by the meta/patch prefix internally).  Returns (logits, new_cache)."""
        cfg = self.cfg
        x = ll.embed(params["embed"], cfg, tokens)
        x = constrain(x, "batch", "seq", "embed_act")
        pos_internal = positions + self._prefix_len
        x, new_cache = stk.run_stack_decode(params["layers"], cfg, x, cache,
                                            positions=pos_internal)
        x = ll.norm(params["final_norm"], x, cfg)
        logits = ll.unembed(params["embed"], cfg, x)
        return logits, new_cache

    # ---- shape cells -------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            text = S - cfg.num_patches if cfg.num_patches else S
            d = {"tokens": jax.ShapeDtypeStruct((B, text), i32),
                 "targets": jax.ShapeDtypeStruct((B, text), i32),
                 "loss_mask": jax.ShapeDtypeStruct((B, text), jnp.float32)}
            if cfg.num_patches:
                d["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_patches, cfg.patch_embed_dim), jnp.bfloat16)
            return d
        if shape.kind == "prefill":
            text = S - cfg.num_patches if cfg.num_patches else S
            d = {"tokens": jax.ShapeDtypeStruct((B, text), i32)}
            if cfg.num_patches:
                d["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_patches, cfg.patch_embed_dim), jnp.bfloat16)
            return d
        # decode: one new token against a cache of size seq_len
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "positions": jax.ShapeDtypeStruct((B,), i32)}


class EncDecLM:
    """Whisper-style encoder-decoder; the audio conv frontend is a stub —
    inputs are precomputed frame embeddings (B, max_source_positions, D)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": ll.embed_specs(cfg),
            "encoder": stk.stack_param_specs(cfg, cfg.encoder_layers),
            "enc_norm": ll.norm_specs(cfg),
            "layers": stk.stack_param_specs(cfg, cross=True),
            "final_norm": ll.norm_specs(cfg),
        }

    def init(self, rng):
        return init_params(self.param_specs(), rng)

    def abstract_params(self):
        return abstract_params(self.param_specs())

    def logical_axes(self):
        return logical_axes(self.param_specs())

    def encode(self, params, frames):
        """frames: (B, T_src, D) stub embeddings -> encoder output."""
        cfg = self.cfg
        B, T, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        x = ll.cast(frames) + _sinusoidal(pos, cfg.d_model).astype(
            ll.COMPUTE_DTYPE)
        x = constrain(x, "batch", "kv_seq", "embed_act")
        x, _aux = stk.run_stack(params["encoder"], cfg, x, positions=pos,
                                causal=False, num_layers=cfg.encoder_layers,
                                remat_policy="none")
        return ll.norm(params["enc_norm"], x, cfg)

    def _embed_dec(self, params, tokens, positions):
        cfg = self.cfg
        x = ll.embed(params["embed"], cfg, tokens)
        x = x + _sinusoidal(positions, cfg.d_model).astype(x.dtype)
        return constrain(x, "batch", "seq", "embed_act")

    def loss(self, params, batch, *, remat_policy: str = "dots"):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        B, S = batch["tokens"].shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed_dec(params, batch["tokens"], pos)
        x, aux = stk.run_stack(params["layers"], cfg, x, positions=pos,
                               causal=True, enc_out=enc,
                               remat_policy=remat_policy)
        x = ll.norm(params["final_norm"], x, cfg)
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(batch["targets"].shape, jnp.float32)
        ce_sum, denom = ll.unembed_xent(params["embed"], cfg, x,
                                        batch["targets"], mask)
        loss = ce_sum / denom + aux
        return loss, {"loss": loss, "aux_loss": aux, "tokens": mask.sum()}

    def init_cache(self, batch: int, max_len: int, abstract: bool = False,
                   kv_dtype=None):
        import jax.numpy as _jnp
        return stk.init_cache(self.cfg, batch, max_len, abstract=abstract,
                              kv_dtype=kv_dtype or _jnp.bfloat16)

    def _cross_kv(self, params, enc):
        """Precompute per-decoder-layer cross K/V: (L,B,T,K,hd) pair."""
        cfg = self.cfg
        wk = params["layers"]["cross"]["wk"]          # (L, D, K*hd)
        wv = params["layers"]["cross"]["wv"]
        from repro.distributed import dp_shard
        from repro.distributed.sharding_rules import current_ctx
        ctx = current_ctx()
        if ctx is not None and ctx.manual:
            dims = dp_shard.rule_manual_dims(ctx, ("layers", "embed",
                                                   "kv_heads"), ctx.manual)
            wrap = tuple(a for a in ctx.mesh.shape if a not in ctx.manual)
            import jax.numpy as _jnp
            auto = dp_shard._auto_entries(ctx, ("layers", "embed",
                                                "kv_heads"), wk.shape,
                                          ctx.manual)
            wk = dp_shard.gather_leaf(wk, dims, dtype=_jnp.bfloat16,
                                      auto_entries=auto, wrap_axes=wrap)
            wv = dp_shard.gather_leaf(wv, dims, dtype=_jnp.bfloat16,
                                      auto_entries=auto, wrap_axes=wrap)
        k = jnp.einsum("btd,ldn->lbtn", ll.cast(enc), ll.cast(wk))
        v = jnp.einsum("btd,ldn->lbtn", ll.cast(enc), ll.cast(wv))
        if "bk" in params["layers"]["cross"]:
            k = k + ll.cast(params["layers"]["cross"]["bk"])[:, None, None]
            v = v + ll.cast(params["layers"]["cross"]["bv"])[:, None, None]
        L, B, T, _ = k.shape
        k = k.reshape(L, B, T, cfg.num_kv_heads, cfg.head_dim)
        v = v.reshape(L, B, T, cfg.num_kv_heads, cfg.head_dim)
        return k, v

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        B, S = batch["tokens"].shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed_dec(params, batch["tokens"], pos)

        hook = stk.manual_layer_hook(cfg, cross=True)
        kv_dt = cache["k"].dtype

        def body(carry, xs):
            xc = carry
            p_layer, _glob = xs
            if hook is not None:
                p_layer = hook(p_layer)
            h = ll.norm(p_layer["ln1"], xc, cfg)
            _q, k, v = ll._project_qkv(p_layer["attn"], cfg, h, h)
            k = constrain(k.astype(kv_dt), "batch", "kv_seq",
                          "kv_heads_act", None)
            v = constrain(v.astype(kv_dt), "batch", "kv_seq",
                          "kv_heads_act", None)
            xc, _aux = stk.block(p_layer, cfg, xc, positions=pos,
                                 is_global=False, causal=True, enc_out=enc)
            return xc, (k, v)

        flags = jnp.zeros(cfg.num_layers, bool)
        h, (ks, vs) = jax.lax.scan(body, x, (params["layers"], flags))
        h = ll.norm(params["final_norm"], h, cfg)
        logits = ll.unembed(params["embed"], cfg, h[:, -1:])

        ck, cv = self._cross_kv(params, enc)
        new_cache = dict(cache)
        T = cache["k"].shape[2]
        write = min(S, T)
        if write == T and S == T:
            new_cache["k"], new_cache["v"] = ks, vs
        else:
            new_cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], ks[:, :, :write], (0, 0, 0, 0, 0))
            new_cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], vs[:, :, :write], (0, 0, 0, 0, 0))
        new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
        new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
        return logits, new_cache

    def decode_step(self, params, cache, tokens, positions):
        cfg = self.cfg
        x = self._embed_dec(params, tokens, positions[:, None])
        x, new_cache = stk.run_stack_decode(params["layers"], cfg, x, cache,
                                            positions=positions)
        x = ll.norm(params["final_norm"], x, cfg)
        logits = ll.unembed(params["embed"], cfg, x)
        return logits, new_cache

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        frames = jax.ShapeDtypeStruct(
            (B, cfg.max_source_positions, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "targets": jax.ShapeDtypeStruct((B, S), i32),
                    "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        if shape.kind == "prefill":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "positions": jax.ShapeDtypeStruct((B,), i32)}


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return DecoderLM(cfg)
