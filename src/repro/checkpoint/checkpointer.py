"""Async, atomic, shard-aware checkpointing (no external deps).

Layout per step::

    <root>/step_00001234.tmp/            # staged, then atomically renamed
        arrays_p0.npz                    # this host's param/opt leaves
        manifest.json                    # leaf names/shapes/dtypes
        aux.json                         # sampler state, loader params, rng

Multi-host: every process writes ``arrays_p{process_index}.npz`` holding its
*addressable* shard of each leaf and the coordinator (process 0) renames the
directory after a barrier; restore reassembles via device_put to the target
sharding.  On this single-process container that degenerates to one file,
but the protocol is the fleet one.

Async: ``save`` snapshots leaves to host memory synchronously (cheap, it's
a device->host copy) then writes in a background thread, so the train loop
only blocks if a previous save is still in flight (bounded queue of 1 —
checkpoint cadence faster than disk means you want backpressure, not OOM).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.utils.tree import flatten_with_names


class Checkpointer:
    def __init__(self, root: str, *, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        os.makedirs(root, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # ---- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ---- save ------------------------------------------------------------------
    def save(self, step: int, state, aux: Optional[Dict[str, Any]] = None,
             *, block: bool = False) -> None:
        self.wait()  # backpressure: at most one save in flight
        named = flatten_with_names(state)
        # snapshot to host (device->host copy) synchronously
        host: Dict[str, np.ndarray] = {}
        for name, leaf in named:
            if leaf is None:
                continue
            host[name] = np.asarray(jax.device_get(leaf))
        aux = dict(aux or {})
        aux["step"] = step

        def _write():
            tmp = self._step_dir(step) + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            pid = jax.process_index()
            np.savez(os.path.join(tmp, f"arrays_p{pid}.npz"), **host)
            manifest = {n: {"shape": list(a.shape), "dtype": str(a.dtype)}
                        for n, a in host.items()}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "aux.json"), "w") as f:
                json.dump(aux, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        t = threading.Thread(target=_write, daemon=True)
        t.start()
        with self._lock:
            self._pending = t
        if block:
            self.wait()

    def wait(self) -> None:
        with self._lock:
            t = self._pending
        if t is not None:
            t.join()
            with self._lock:
                if self._pending is t:
                    self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---- restore -----------------------------------------------------------------
    def restore(self, state_template, step: Optional[int] = None,
                *, shardings=None) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``state_template`` (values ignored).

        ``shardings``: optional pytree of NamedSharding for resharded
        restore (elastic re-mesh: new topology, same checkpoint).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._step_dir(step)
        pid = jax.process_index()
        path = os.path.join(d, f"arrays_p{pid}.npz")
        if not os.path.exists(path):  # elastic restart: host id changed
            path = os.path.join(d, "arrays_p0.npz")
        arrays = np.load(path)
        with open(os.path.join(d, "aux.json")) as f:
            aux = json.load(f)

        named = flatten_with_names(state_template)
        shard_named = flatten_with_names(shardings) if shardings is not None \
            else [(n, None) for n, _ in named]
        leaves = []
        for (name, tmpl), (_n2, shd) in zip(named, shard_named):
            if tmpl is None:
                leaves.append(None)
                continue
            arr = arrays[name]
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.device_put(arr))
        treedef = jax.tree_util.tree_structure(state_template)
        return jax.tree_util.tree_unflatten(treedef, leaves), aux
