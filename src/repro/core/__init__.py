"""DPT core package.  Imports are lazy to avoid data<->core import cycles
(data.loader uses core.monitor; core.dpt uses data.loader)."""
import importlib

_EXPORTS = {
    "DPT": "repro.core.dpt",
    "DPTConfig": "repro.core.dpt",
    "DPTResult": "repro.core.dpt",
    "FleetResult": "repro.core.dpt",
    "MultiHostDPT": "repro.core.dpt",
    "Trial": "repro.core.dpt",
    "default_params": "repro.core.dpt",
    "MemoryBudget": "repro.core.monitor",
    "MemoryMonitor": "repro.core.monitor",
    "MemoryOverflow": "repro.core.monitor",
    "LoaderSimulator": "repro.core.simulator",
    "MachineProfile": "repro.core.simulator",
    "SimResult": "repro.core.simulator",
    "LoaderEvaluator": "repro.core.evaluators",
    "SimulatorEvaluator": "repro.core.evaluators",
    "DPTCache": "repro.core.cache",
    "search": "repro.core",
}


def __getattr__(name):
    if name == "search":
        return importlib.import_module("repro.core.search")
    if name in _EXPORTS:
        mod = importlib.import_module(_EXPORTS[name])
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
