"""Fleet simulation for MultiHostDPT and the fleet control plane:
heterogeneous hosts (stragglers, degraded storage, fewer free cores) built
from perturbed machine/storage profiles, plus deterministic join/leave/
degrade schedules that drive elastic-fleet scenarios.  Used by
benchmarks/bench_multihost.py, benchmarks/bench_fleet.py and the FT tests.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence

from repro.core.evaluators import SimulatorEvaluator
from repro.core.simulator import LoaderSimulator, MachineProfile
from repro.data.storage import StorageProfile


@dataclasses.dataclass(frozen=True)
class HostSpec:
    name: str
    machine: MachineProfile
    storage: StorageProfile


def degraded(machine: MachineProfile, *, cpu_scale: float = 1.0,
             io_scale: float = 1.0, ram_scale: float = 1.0) -> MachineProfile:
    return dataclasses.replace(
        machine,
        physical_cores=max(1, int(machine.physical_cores * cpu_scale)),
        logical_cores=max(1, int(machine.logical_cores * cpu_scale)),
        host_ram=machine.host_ram * ram_scale,
    )


def degraded_storage(storage: StorageProfile, *,
                     bw_scale: float = 1.0,
                     latency_scale: float = 1.0) -> StorageProfile:
    return dataclasses.replace(
        storage,
        storage_bw=storage.storage_bw * bw_scale,
        io_latency_s=storage.io_latency_s * latency_scale,
    )


def make_fleet(base_machine: MachineProfile, base_storage: StorageProfile,
               *, num_hosts: int, slow_hosts: Sequence[int] = (),
               slow_cpu_scale: float = 0.5,
               slow_io_scale: float = 0.3) -> List[HostSpec]:
    """num_hosts homogeneous hosts with ``slow_hosts`` degraded (the
    straggler-injection scenario)."""
    fleet = []
    for h in range(num_hosts):
        if h in slow_hosts:
            m = degraded(base_machine, cpu_scale=slow_cpu_scale)
            s = degraded_storage(base_storage, bw_scale=slow_io_scale,
                                 latency_scale=1.0 / slow_io_scale)
        else:
            m, s = base_machine, base_storage
        fleet.append(HostSpec(f"host{h}", m, s))
    return fleet


def fleet_evaluators(fleet: Sequence[HostSpec], *, batch_size: int,
                     device_ram: Optional[float] = None
                     ) -> List[SimulatorEvaluator]:
    return [SimulatorEvaluator(LoaderSimulator(h.storage, h.machine),
                               batch_size=batch_size, device_ram=device_ram)
            for h in fleet]


# --------------------------------------------------------------------------
# elastic-fleet scenario schedules (join / leave / degrade at a step)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """One scheduled perturbation of the running fleet.

    ``kind`` is ``"leave"`` (the host goes silent: heartbeat timeout ->
    coordinator reshards around it), ``"join"`` (a new host enters at the
    barrier) or ``"degrade"`` (the host's CPU/IO capacity is scaled —
    what the straggler detector and re-consensus react to).

    Control-plane faults (transport-mode fleets, DESIGN.md §8):
    ``"partition"`` cuts the host's link to the coordinator (the host
    keeps streaming on latched params), ``"heal"`` restores it, and
    ``"coord_crash"`` kills the coordinator itself (``host`` names the
    coordinator endpoint; a standby's lease-driven promotion recovers) —
    these drive the FaultyTransport, not the host processes.
    """
    step: int
    kind: str        # "leave"|"join"|"degrade"|"partition"|"heal"|"coord_crash"
    host: str
    cpu_scale: float = 1.0            # degrade only
    io_scale: float = 1.0             # degrade only

    def __post_init__(self):
        if self.kind not in ("leave", "join", "degrade",
                             "partition", "heal", "coord_crash"):
            raise ValueError(f"unknown fleet event kind {self.kind!r}")


class FleetSchedule:
    """Deterministic event timeline for elastic-fleet runs.

    The driver calls ``at(step)`` once per lockstep round and applies the
    returned events (kill the host's driver loop, construct + ``join`` a
    new agent, degrade the host's storage profile).  Mirrors
    ``FailureInjector`` but speaks the full join/leave/degrade vocabulary
    the control plane handles.
    """

    def __init__(self, events: Sequence[FleetEvent] = ()):
        self._by_step: Dict[int, List[FleetEvent]] = defaultdict(list)
        for e in events:
            self._by_step[e.step].append(e)
        self.fired: List[FleetEvent] = []

    def add(self, event: FleetEvent) -> "FleetSchedule":
        self._by_step[event.step].append(event)
        return self

    def at(self, step: int) -> List[FleetEvent]:
        events = self._by_step.pop(step, [])
        self.fired.extend(events)
        return events

    @property
    def pending(self) -> int:
        return sum(len(v) for v in self._by_step.values())
