"""Virtual-time loader simulator.

This container has ONE physical core, so multi-core worker-scaling curves
cannot be measured in wall clock.  The paper-table benchmarks therefore run
DPT against this discrete(-ish) event model, which captures every mechanism
the paper attributes its results to:

* worker parallelism with CPU contention: decode throughput scales with
  min(nWorker, available_logical_cores); the paper's "optimal = 10 of 12
  logical cores because main + loader processes occupy two" is the
  ``reserved_cores`` term;
* shared storage bandwidth with per-stream limits and congestion beyond
  ``io_streams`` concurrent readers (why large-item / cold-epoch optima sit
  at moderate worker counts);
* an OS page cache: epoch >= 2 reads hit RAM for the cached fraction; the
  cache competes with loader memory (worker overhead + prefetch buffers),
  which is why second-epoch optima drop for datasets larger than RAM;
* prefetch-factor pipelining: overlap of a worker's IO and CPU phases
  improves sharply from j=1 and saturates, with a small deterministic
  jitter making the exact optimum unpredictable (paper Fig. 2b);
* memory overflow: footprint beyond host RAM raises the same
  ``MemoryOverflow`` the real loader raises (paper's N/A cells).

The SAME DPT code drives this simulator and the real wall-clock loader
(see core/evaluators.py); only the objective callback differs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Optional

from repro.core.monitor import MemoryOverflow, estimate_loader_footprint
from repro.data.storage import StorageProfile


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    """Host resources (the paper's testbed by default: i7-8700K, 64 GB)."""
    physical_cores: int = 6
    logical_cores: int = 12
    reserved_cores: int = 2          # main process + loader main process
    num_devices: int = 1             # G in Algorithm 1
    host_ram: float = 64e9
    os_reserved: float = 4e9
    io_streams: int = 6              # concurrent reads before bw congestion
    worker_overhead_bytes: float = 1.2e9   # per-worker process footprint
    hyperthread_eff: float = 0.5     # logical cores beyond physical scale
    amdahl_serial: float = 0.06      # serial fraction of decode parallelism
    thrash_exp: float = 1.35         # oversubscription (ctx-switch) penalty
    io_congestion: float = 0.08      # bw loss per reader beyond io_streams
    device_bw: float = 12e9          # host->device interconnect
    # fraction of the free-RAM page cache that actually serves warm-epoch
    # reads (1.0 = the neutral legacy model: every free byte caches
    # perfectly).  Real hosts evict under competing pressure; a value < 1
    # is what makes an EXPLICITLY pinned cache tier (cache_budget_bytes)
    # worth its footprint on the warm-epoch grid (DESIGN.md §7).
    page_cache_eff: float = 1.0

    @property
    def effective_cores(self) -> float:
        phys = self.physical_cores
        extra = max(0, self.logical_cores - phys)
        return phys + self.hyperthread_eff * extra

    def _over_penalty(self, k: int) -> float:
        """Context-switch thrash once (workers + reserved) exceed logical
        cores — the paper's 'optimal = logical cores - 2' observation."""
        over = (k + self.reserved_cores) / self.logical_cores
        return 1.0 if over <= 1.0 else over ** self.thrash_exp

    def cpu_speedup(self, k: int) -> float:
        """Parallel decode speedup of k workers: Amdahl-damped linear gain,
        thrash-penalized beyond the free logical cores."""
        k = max(1, k)
        amdahl = k / (1.0 + self.amdahl_serial * (k - 1))
        return amdahl / self._over_penalty(k)

    def io_worker_eff(self, k: int) -> float:
        """Effective concurrent IO requesters (same thrash shape: an
        oversubscribed host also issues requests late)."""
        return max(1, k) / self._over_penalty(k)


@dataclasses.dataclass(frozen=True)
class SimResult:
    seconds: float
    peak_bytes: float
    warm_fraction: float
    io_seconds: float
    cpu_seconds: float
    overflowed: bool = False


def _jitter(*keys, amp: float = 0.03) -> float:
    """Deterministic pseudo-noise in [1-amp, 1+amp]."""
    blob = "|".join(str(k) for k in keys).encode()
    h = int.from_bytes(hashlib.sha256(blob).digest()[:8], "big")
    return 1.0 + amp * (2.0 * (h / 2**64) - 1.0)


class LoaderSimulator:
    def __init__(self, storage: StorageProfile, machine: MachineProfile,
                 *, model_host_bytes: float = 2e9):
        self.sp = storage
        self.mp = machine
        self.model_host_bytes = model_host_bytes

    # ---- memory model -------------------------------------------------------
    def batch_bytes(self, batch_size: int) -> float:
        return batch_size * self.sp.decoded

    def footprint(self, batch_size: int, nworker: int, nprefetch: int,
                  device_prefetch: int = 2) -> float:
        base = estimate_loader_footprint(
            self.batch_bytes(batch_size), nworker, nprefetch, device_prefetch)
        return base + max(1, nworker) * self.mp.worker_overhead_bytes

    def device_bytes(self, batch_size: int, device_prefetch: int = 2) -> float:
        return (1 + device_prefetch) * self.batch_bytes(batch_size)

    # ---- timing model -------------------------------------------------------
    def simulate(self, *, batch_size: int, num_batches: int, nworker: int,
                 nprefetch: int, epoch: int = 0, device_prefetch: int = 2,
                 device_ram: Optional[float] = None,
                 check_overflow: bool = True,
                 locality_chunk: int = 0, host_count: int = 1,
                 layout: str = "host_major",
                 cache_budget_bytes: float = 0.0,
                 slow_lane_workers: int = 0) -> SimResult:
        sp, mp = self.sp, self.mp
        K = max(1, nworker)
        j = max(1, nprefetch)
        budget = max(0.0, float(cache_budget_bytes))
        k_lane = max(0, slow_lane_workers)
        # heavy-tailed per-item cost (DESIGN.md §9): tail_fraction of items
        # cost tail_mult x.  Neutral defaults (0 / 1) change nothing below.
        heavy = sp.tail_fraction > 0.0 and sp.tail_mult > 1.0

        foot = self.footprint(batch_size, nworker, nprefetch, device_prefetch)
        foot += budget                 # the pinned tier is loader memory
        foot += k_lane * mp.worker_overhead_bytes   # lane workers are real
        avail_ram = mp.host_ram - mp.os_reserved - self.model_host_bytes
        if check_overflow and foot > avail_ram:
            raise MemoryOverflow(
                f"simulated loader footprint {foot/1e9:.1f}GB > "
                f"available {avail_ram/1e9:.1f}GB")
        if check_overflow and device_ram is not None:
            if self.device_bytes(batch_size, device_prefetch) > device_ram:
                raise MemoryOverflow("simulated device memory overflow")

        # page cache: what's left after the loader's own memory (which now
        # includes the pinned cache tier).  The tier serves its hot set
        # with certainty on epochs >= 1; the page cache serves a
        # page_cache_eff fraction of what fits in the REMAINING free RAM —
        # the two are disjoint (hit-ratio x latency-delta pricing of the
        # cache axis: warm-fraction gain vs the footprint it pins).
        cache_cap = max(0.0, avail_ram - foot)
        if epoch == 0:
            warm = 0.0
        else:
            tier_warm = min(1.0, budget / sp.dataset_bytes)
            warm = min(1.0, tier_warm + mp.page_cache_eff
                       * cache_cap / sp.dataset_bytes)

        items = num_batches * batch_size

        # --- IO stage throughput (items/s) ---
        # Seek-queueing latency grows with concurrent readers (fitted from
        # paper Table 1b, see StorageProfile); aggregate bandwidth congests
        # beyond io_streams readers; the bw ceiling always applies.  Batched
        # reads coalesce contiguous items into runs (StorageProfile
        # .coalesced_run_len, 1.0 = legacy per-item requests), amortizing
        # the base latency over the run — bandwidth is charged in full.
        # Chunked sampling (locality_chunk > 1, DESIGN.md §5): a batch's
        # sorted misses coalesce into runs of about min(chunk, batch) items
        # — the measured effect of ShardedSampler's chunked orders, priced
        # here so DPT grids resolve the locality axis in virtual time.
        # 0/1 leaves the profile's own run length (neutral default).
        # ``batch_size`` is this HOST's batch: under the host-major shard
        # layout (DESIGN.md §6) per-host runs stay ~min(chunk, batch) at
        # any host count.  The legacy strided layout gets NO chunking
        # benefit at H > 1: every H-th element of a within-chunk-shuffled
        # run is a near-random value, so strict-contiguity coalescing
        # (coalesce_runs / achieved_run_len) collapses to ~1 — measured
        # 1.2-1.7 at C=16, H in {2,4}, which the profile's own run
        # length already bounds.
        run = max(1.0, sp.coalesced_run_len)
        if locality_chunk and locality_chunk > 1:
            if layout != "strided" or max(1, host_count) == 1:
                run = max(run, float(min(locality_chunk, batch_size)))
        lat_k = sp.io_latency_s * (1.0 + sp.seek_congestion * K)
        agg_bw = sp.storage_bw / (1.0 + mp.io_congestion
                                  * max(0, K - mp.io_streams))
        per_request = lat_k / run + sp.item_bytes * K / agg_bw
        rate_cold = min(mp.io_worker_eff(K) / per_request,
                        agg_bw / sp.item_bytes)
        rate_warm = sp.ram_bw / sp.item_bytes
        rate_io = 1.0 / ((1.0 - warm) / rate_cold + warm / rate_warm)

        # --- CPU stage throughput (items/s) ---
        # The vectorized batch transform amortizes the per-item fixed decode
        # cost (StorageProfile.vectorized_decode_fixed_s; None = per-sample)
        cpu_item_s = (sp.effective_decode_fixed_s
                      + sp.decode_cpu_s_per_byte * sp.decoded)
        base_cpu_item_s = cpu_item_s
        if heavy:
            # tail items inflate the MEAN decode cost regardless of lanes:
            # the work still has to happen somewhere.
            cpu_item_s *= 1.0 + sp.tail_fraction * (sp.tail_mult - 1.0)
        rate_cpu = mp.cpu_speedup(K) / cpu_item_s
        if k_lane:
            # lane workers contend for the same cores as the fast lane
            rate_cpu *= mp._over_penalty(K) / mp._over_penalty(K + k_lane)

        # --- pipeline composition: prefetch_factor controls IO/CPU overlap
        # within each worker (j=1: serialized; j>=2: stages overlap, gains
        # saturating) ---
        t_io = 1.0 / rate_io
        t_cpu = 1.0 / rate_cpu
        overlap = 1.0 - 1.0 / (1.0 + 1.2 * (j - 0.5))
        per_item = max(t_io, t_cpu) + (1.0 - overlap) * min(t_io, t_cpu)
        per_item *= _jitter("cell", K, j, sp.item_bytes, batch_size)
        if k_lane:
            # dispatch-side overhead of classification + lane bookkeeping;
            # keeps the grid honest on uniform profiles (k=0 must win there)
            per_item *= (1.0 + 0.02 * k_lane) * _jitter("lane", K, j, k_lane)

        # --- makespan + pipeline fill (first batch must fully arrive) ---
        fill_item = per_request if (epoch == 0 or warm < 1.0) else cpu_item_s
        total = items * per_item + batch_size * fill_item / max(1, min(K, j + 1))

        # --- ordered-pipe straggler stalls (DESIGN.md §9) ---
        # A batch containing a tail item takes excess_s longer than its
        # neighbours.  With ordered delivery the reorder window can absorb
        # (window x t_batch) of that before the whole pipe stalls; a slow
        # lane both widens the window and starts predicted-slow batches
        # ``lookahead`` batches ahead of schedule, so each lane worker
        # hides another LOOK x t_batch of excess.
        if heavy:
            t_batch = batch_size * per_item
            excess_s = (sp.tail_mult - 1.0) * base_cpu_item_s \
                / mp.cpu_speedup(K) * K   # one straggler decodes serially
            p_slow = 1.0 - (1.0 - sp.tail_fraction) ** batch_size
            look = 8          # LoaderParams.slow_lane_lookahead default
            absorb = (K * j + K + k_lane + look * k_lane) * t_batch
            stall = max(0.0, excess_s - absorb)
            total += num_batches * p_slow * stall

        # --- host->device transfer; hidden when device_prefetch >= 2 ---
        xfer = num_batches * self.batch_bytes(batch_size) / mp.device_bw
        hidden = min(1.0, 0.55 * device_prefetch)
        total += xfer * (1.0 - hidden)

        return SimResult(seconds=total, peak_bytes=foot, warm_fraction=warm,
                         io_seconds=items * t_io, cpu_seconds=items * t_cpu)
