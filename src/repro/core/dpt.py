"""Dataloader Parameter Tuner (DPT) — the paper's Algorithm 1, faithfully,
plus the multi-host fleet extension.

Faithful part (``DPT.run``):
    nWorker starts at G (accelerator count) and increases by G up to N
    (CPU cores, final rung clamped to N); for each, nPrefetch sweeps 1..P;
    each cell measures the dataloader transfer time; memory overflow breaks
    the inner loop and moves to the next worker count; the argmin is
    returned.

The tuner is decoupled from *how* a cell is measured: an ``Evaluator``
returns ``TransferStats`` (real wall-clock loader, or the virtual-time
simulator — see core/evaluators.py).  That is what lets the same algorithm
drive unit tests, paper-table benchmarks and the multi-host simulation.

The search loop itself now lives in the unified strategy layer
(``repro.tuning``): ``DPT.run`` delegates to the registered ``"grid"``
strategy, and this module keeps the shared dataclasses (DPTConfig,
Trial, DPTResult) plus the fleet tuner built on top.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.monitor import MemoryOverflow
from repro.data.loader import TransferStats

Evaluator = Callable[..., TransferStats]  # (nworker, nprefetch, **kw)


@dataclasses.dataclass(frozen=True)
class DPTConfig:
    num_cpu_cores: Optional[int] = None      # N  (default: os.cpu_count())
    num_devices: Optional[int] = None        # G  (default: local devices)
    max_prefetch: int = 8                    # P
    min_prefetch: int = 1
    num_batches: int = 32                    # measurement budget per cell
    epoch: int = 0                           # 0 = cold (1st), >=1 = warm
    # beyond-paper third grid axis (DESIGN.md §5): candidate sampler
    # locality_chunk values (0 = fully random).  None keeps the search on
    # the paper's (nWorker, nPrefetch) plane and never passes the kwarg to
    # the evaluator — existing two-argument evaluators are untouched.
    locality_chunks: Optional[Tuple[int, ...]] = None
    # beyond-paper fourth grid axis (DESIGN.md §7): candidate cross-epoch
    # cache budgets in bytes (0 = cache off).  Same contract: None keeps
    # the kwarg away from the evaluator entirely.
    cache_budgets: Optional[Tuple[int, ...]] = None
    # beyond-paper fifth grid axis (DESIGN.md §9): candidate slow-lane
    # worker counts (0 = dual-lane off).  Same contract: None keeps the
    # kwarg away from the evaluator entirely.
    slow_lanes: Optional[Tuple[int, ...]] = None
    # beyond-paper sixth grid axis (DESIGN.md §11): candidate GLOBAL batch
    # geometries (0 = keep the loader's current global batch).  Outermost
    # of all — geometry changes re-shape every inner measurement.  Same
    # contract: None never passes the kwarg to the evaluator.
    geometries: Optional[Tuple[int, ...]] = None

    def resolve(self) -> Tuple[int, int]:
        n = self.num_cpu_cores
        if n is None:
            n = os.cpu_count() or 1
        g = self.num_devices
        if g is None:
            try:
                import jax
                g = jax.local_device_count()
            except Exception:  # pragma: no cover
                g = 1
        return n, max(1, g)


@dataclasses.dataclass
class Trial:
    nworker: int
    nprefetch: int
    seconds: float
    overflowed: bool = False
    peak_bytes: float = 0.0
    # per-batch samples when the evaluator measured wall clock (None for
    # aggregate-only evaluators like the simulator)
    batch_seconds: Optional[List[float]] = None
    # sampler locality the cell was measured with (0 = random order / the
    # locality axis was not searched)
    locality_chunk: int = 0
    # cross-epoch cache budget the cell was measured with (0 = cache off /
    # the cache axis was not searched)
    cache_budget_bytes: int = 0
    # slow-lane workers the cell was measured with (0 = dual-lane off /
    # the lane axis was not searched)
    slow_lane_workers: int = 0
    # global batch the cell was measured with (0 = the loader's own / the
    # geometry axis was not searched)
    global_batch: int = 0


@dataclasses.dataclass
class DPTResult:
    nworker: int
    nprefetch: int
    optimal_time: float
    trials: List[Trial]
    default_time: Optional[float] = None
    locality_chunk: int = 0
    cache_budget_bytes: int = 0
    slow_lane_workers: int = 0
    global_batch: int = 0

    @property
    def speedup_vs_default(self) -> Optional[float]:
        if self.default_time is None or self.optimal_time == 0:
            return None
        return self.default_time / self.optimal_time

    @property
    def time_reduction_pct(self) -> Optional[float]:
        """Percent of the default-parameter time saved by the optimum
        (positive = improvement)."""
        if self.default_time is None or self.default_time == 0:
            return None
        return 100.0 * (self.default_time - self.optimal_time) / self.default_time


def default_params(num_cpu_cores: Optional[int] = None) -> Tuple[int, int]:
    """PyTorch's defaults the paper compares against: workers = cores/2,
    prefetch_factor = 2."""
    n = num_cpu_cores if num_cpu_cores is not None else (os.cpu_count() or 1)
    return max(1, n // 2), 2


class DPT:
    def __init__(self, evaluator: Evaluator,
                 config: DPTConfig = DPTConfig()):
        self.evaluator = evaluator
        self.config = config

    def _measure(self, i: int, j: int) -> TransferStats:
        return self.evaluator(i, j, num_batches=self.config.num_batches,
                              epoch=self.config.epoch)

    def run(self, *, measure_default: bool = True) -> DPTResult:
        """Algorithm 1 (served by the unified ``"grid"`` strategy; see
        ``repro.tuning.strategies.GridSearch`` for the line mapping)."""
        from repro.tuning import tune
        return tune(evaluator=self.evaluator, strategy="grid",
                    config=self.config, measure_default=measure_default)

    # ---- full grid (figures 2-4) --------------------------------------------
    def grid(self, workers: Sequence[int],
             prefetches: Sequence[int]) -> Dict[Tuple[int, int], float]:
        out: Dict[Tuple[int, int], float] = {}
        for i in workers:
            for j in prefetches:
                try:
                    out[(i, j)] = self._measure(i, j).seconds
                except MemoryOverflow:
                    out[(i, j)] = math.inf
        return out


# --------------------------------------------------------------------------
# multi-host fleet tuning (beyond paper; DESIGN.md §2 "Multi-pod semantics")
# --------------------------------------------------------------------------
@dataclasses.dataclass
class FleetResult:
    mode: str                             # "uniform" | "per_host"
    per_host: List[DPTResult]
    fleet_params: List[Tuple[int, int]]   # chosen (nworker, nprefetch)/host
    fleet_time: float                     # max over hosts (lockstep step time)
    uniform_params: Optional[Tuple[int, int]] = None


class MultiHostDPT:
    """Tunes a fleet where hosts may be heterogeneous (stragglers).

    The fleet steps in lockstep, so the effective transfer time is the MAX
    over hosts.  Two modes:

    * ``per_host``: each host tunes independently (optimal when per-host
      configs are allowed — independent minimization minimizes the max);
    * ``uniform``: one (nWorker, nPrefetch) for every host (common fleet
      constraint) chosen to minimize the max over hosts — a straggler-aware
      consensus the single-machine paper has no analogue of.
    """

    def __init__(self, evaluators: Sequence[Evaluator],
                 config: DPTConfig = DPTConfig()):
        self.evaluators = list(evaluators)
        self.config = config

    def run_per_host(self) -> FleetResult:
        results = [DPT(ev, self.config).run(measure_default=False)
                   for ev in self.evaluators]
        params = [(r.nworker, r.nprefetch) for r in results]
        fleet_time = max(r.optimal_time for r in results)
        return FleetResult("per_host", results, params, fleet_time)

    def run_uniform(self) -> FleetResult:
        """Per-host sweeps + straggler-aware consensus.  The consensus math
        lives in the fleet control plane (``repro.tuning.fleet``), which the
        FleetCoordinator also uses for online re-consensus."""
        from repro.tuning.fleet import uniform_consensus
        results = [DPT(ev, self.config).run(measure_default=False)
                   for ev in self.evaluators]
        best, fleet_time = uniform_consensus(results)
        return FleetResult("uniform", results, [best] * len(results),
                           fleet_time, uniform_params=best)
