"""Beyond-paper search strategies — compatibility shims.

The implementations moved to the unified strategy layer in
``repro.tuning`` (one ``TuningStrategy`` protocol + registry, shared
Trial bookkeeping and MemoryOverflow semantics); these functions keep the
original signatures and delegate, so existing call sites and the
benchmarks are unchanged:

* ``successive_halving``   -> ``tune(strategy="successive_halving", ...)``
* ``coordinate_hillclimb`` -> ``tune(strategy="hillclimb", ...)``
* ``tuned_with_warmstart`` -> ``tune(strategy="warmstart_hillclimb", ...)``
* ``goodput_tune``         -> ``tune(strategy="goodput", ...)``
* ``cost_model_warmstart`` — zero-measurement analytic seed (re-exported
  from ``repro.tuning.strategies``).
"""
from __future__ import annotations

from typing import Tuple

from repro.core.dpt import DPTConfig, DPTResult
from repro.core.simulator import MachineProfile
from repro.data.storage import StorageProfile
from repro.tuning.base import tune
from repro.tuning.strategies import (  # noqa: F401  (compat re-exports)
    CostModelPrediction,
    cost_model_warmstart,
)


def successive_halving(evaluator, *, config: DPTConfig = DPTConfig(),
                       eta: int = 3, min_batches: int = 4) -> DPTResult:
    return tune(evaluator=evaluator, strategy="successive_halving",
                config=config, eta=eta, min_batches=min_batches)


def coordinate_hillclimb(evaluator, *, start: Tuple[int, int],
                         config: DPTConfig = DPTConfig(),
                         max_steps: int = 24) -> DPTResult:
    return tune(evaluator=evaluator, strategy="hillclimb", config=config,
                start=start, max_steps=max_steps)


def tuned_with_warmstart(evaluator, storage: StorageProfile,
                         machine: MachineProfile, *, batch_size: int,
                         config: DPTConfig = DPTConfig()) -> DPTResult:
    return tune(evaluator=evaluator, strategy="warmstart_hillclimb",
                config=config, storage=storage, machine=machine,
                batch_size=batch_size)


def goodput_tune(evaluator, *, step_time_s: float, num_batches: int,
                 config: DPTConfig = DPTConfig(),
                 margin: float = 0.1) -> DPTResult:
    return tune(evaluator=evaluator, strategy="goodput", config=config,
                step_time_s=step_time_s, num_batches=num_batches,
                margin=margin)
