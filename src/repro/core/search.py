"""Beyond-paper search strategies.

The paper's grid search is exhaustive: O(N/G * P) measured cells, each a
full timed run.  On a 1000-node fleet that cost is paid per machine class;
these strategies cut it by 5-20x while landing on the same optimum on every
profile we test:

* ``successive_halving``  — measure all cells with a tiny batch budget,
  keep the best 1/eta, multiply the budget, repeat (Hyperband-style rung
  schedule; noisy-but-cheap early rungs are enough to discard most cells).
* ``cost_model_warmstart`` + ``coordinate_hillclimb`` — napkin-math the
  optimum from the machine/storage profile (zero measurements), then
  coordinate-descend (+/-G workers, +/-1 prefetch) with real measurements
  until no neighbor improves.  Typical cost: < 12 measurements vs 96 for
  the paper's grid on the testbed profile.

Both honour the same MemoryOverflow semantics as Algorithm 1.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.dpt import DPTConfig, DPTResult, Evaluator, Trial
from repro.core.monitor import MemoryOverflow
from repro.core.simulator import LoaderSimulator, MachineProfile
from repro.data.storage import StorageProfile


def _measure(ev: Evaluator, i: int, j: int, num_batches: int,
             epoch: int) -> float:
    try:
        s = ev(i, j, num_batches=num_batches, epoch=epoch)
        return math.inf if s.overflowed else s.seconds
    except MemoryOverflow:
        return math.inf


def successive_halving(evaluator: Evaluator, *, config: DPTConfig = DPTConfig(),
                       eta: int = 3, min_batches: int = 4) -> DPTResult:
    N, G = config.resolve()
    cells: List[Tuple[int, int]] = [
        (i, j) for i in range(G, N + 1, G)
        for j in range(config.min_prefetch, config.max_prefetch + 1)]
    budget = min_batches
    trials: List[Trial] = []
    scores: Dict[Tuple[int, int], float] = {}
    while True:
        scores = {}
        for (i, j) in cells:
            t = _measure(evaluator, i, j, budget, config.epoch)
            scores[(i, j)] = t
            trials.append(Trial(i, j, t, overflowed=not math.isfinite(t)))
        alive = [c for c in cells if math.isfinite(scores[c])]
        if not alive:
            raise MemoryOverflow("all cells overflow")
        alive.sort(key=lambda c: scores[c])
        if len(alive) <= 2 or budget >= config.num_batches:
            best = alive[0]
            return DPTResult(best[0], best[1], scores[best], trials)
        cells = alive[:max(2, len(alive) // eta)]
        budget = min(budget * eta, config.num_batches)


@dataclasses.dataclass
class CostModelPrediction:
    nworker: int
    nprefetch: int
    predicted_seconds: float


def cost_model_warmstart(storage: StorageProfile, machine: MachineProfile,
                         *, batch_size: int, config: DPTConfig = DPTConfig(),
                         ) -> CostModelPrediction:
    """Zero-measurement analytic optimum from the simulator's own cost model
    (the napkin math, mechanized).  Used to seed the hillclimb on a new
    machine/dataset pair before any wall-clock run."""
    sim = LoaderSimulator(storage, machine)
    N, G = config.resolve()
    best = None
    for i in range(G, N + 1, G):
        for j in range(config.min_prefetch, config.max_prefetch + 1):
            try:
                r = sim.simulate(batch_size=batch_size, num_batches=32,
                                 nworker=i, nprefetch=j, epoch=config.epoch)
            except MemoryOverflow:
                break
            if best is None or r.seconds < best[2]:
                best = (i, j, r.seconds)
    if best is None:
        raise MemoryOverflow("cost model: every cell overflows")
    return CostModelPrediction(*best)


def coordinate_hillclimb(evaluator: Evaluator, *, start: Tuple[int, int],
                         config: DPTConfig = DPTConfig(),
                         max_steps: int = 24) -> DPTResult:
    N, G = config.resolve()
    lo_j, hi_j = config.min_prefetch, config.max_prefetch

    def clamp(i, j):
        i = max(G, min(N, (i // G) * G if i % G else i))
        return i, max(lo_j, min(hi_j, j))

    cur = clamp(*start)
    trials: List[Trial] = []
    seen: Dict[Tuple[int, int], float] = {}

    def score(cell):
        if cell not in seen:
            seen[cell] = _measure(evaluator, cell[0], cell[1],
                                  config.num_batches, config.epoch)
            trials.append(Trial(cell[0], cell[1], seen[cell],
                                overflowed=not math.isfinite(seen[cell])))
        return seen[cell]

    best_t = score(cur)
    for _ in range(max_steps):
        i, j = cur
        neighbors = [clamp(i + G, j), clamp(i - G, j),
                     clamp(i, j + 1), clamp(i, j - 1)]
        cand = min(neighbors, key=score)
        if score(cand) + 1e-12 < best_t:
            cur, best_t = cand, score(cand)
        else:
            break
    if not math.isfinite(best_t):
        raise MemoryOverflow("hillclimb found no feasible cell")
    return DPTResult(cur[0], cur[1], best_t, trials)


def tuned_with_warmstart(evaluator: Evaluator, storage: StorageProfile,
                         machine: MachineProfile, *, batch_size: int,
                         config: DPTConfig = DPTConfig()) -> DPTResult:
    pred = cost_model_warmstart(storage, machine, batch_size=batch_size,
                                config=config)
    return coordinate_hillclimb(evaluator,
                                start=(pred.nworker, pred.nprefetch),
                                config=config)


# --------------------------------------------------------------------------
# goodput mode: tune to the accelerator's consumption rate, not to max
# --------------------------------------------------------------------------
def goodput_tune(evaluator: Evaluator, *, step_time_s: float,
                 num_batches: int, config: DPTConfig = DPTConfig(),
                 margin: float = 0.1) -> DPTResult:
    """Minimal-resource tuning: the loader only needs to outpace the model.

    Finds the smallest (nworker, nprefetch) whose per-batch transfer time is
    <= step_time * (1 - margin); falls back to the global optimum if no cell
    meets the target.  Frees host cores on fleet nodes where the model step
    (not the loader) is the bottleneck — the paper's objective (max loader
    speed) over-provisions there.
    """
    N, G = config.resolve()
    target = step_time_s * (1.0 - margin) * num_batches
    trials: List[Trial] = []
    best_any: Optional[Tuple[int, int, float]] = None
    for i in range(G, N + 1, G):
        for j in range(config.min_prefetch, config.max_prefetch + 1):
            t = _measure(evaluator, i, j, num_batches, config.epoch)
            trials.append(Trial(i, j, t, overflowed=not math.isfinite(t)))
            if not math.isfinite(t):
                break
            if best_any is None or t < best_any[2]:
                best_any = (i, j, t)
            if t <= target:
                return DPTResult(i, j, t, trials)
    if best_any is None:
        raise MemoryOverflow("goodput: every cell overflows")
    return DPTResult(best_any[0], best_any[1], best_any[2], trials)
