"""Evaluators: the measurement side of DPT's hypothesis loop.

Both expose ``(nworker, nprefetch, *, num_batches, epoch) -> TransferStats``
so Algorithm 1, the beyond-paper search strategies and the fleet tuner are
indifferent to whether a cell is a real wall-clock run or a virtual-time
simulation.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.core.monitor import MemoryOverflow
from repro.core.simulator import LoaderSimulator
from repro.data.loader import DataLoader, LoaderParams, TransferStats


class LoaderEvaluator:
    """Measures the real loader (threads, queues, device_put) in wall clock."""

    def __init__(self, loader: DataLoader, *, to_device: bool = True,
                 device_prefetch: int = 2):
        self.loader = loader
        self.to_device = to_device
        self.device_prefetch = device_prefetch
        self.calls = 0

    def __call__(self, nworker: int, nprefetch: int, *, num_batches: int = 16,
                 epoch: int = 0,
                 locality_chunk: Optional[int] = None,
                 cache_budget_bytes: Optional[int] = None,
                 slow_lane_workers: Optional[int] = None,
                 global_batch: Optional[int] = None) -> TransferStats:
        self.calls += 1
        # replace() keeps the loader's delivery knobs (fast_path, zero_copy,
        # ordered, use_processes, ...) so trials measure the same machinery
        # the live stream runs.  The locality, cache, slow-lane and
        # geometry axes are passed as measurement-only overrides —
        # candidate chunk sizes / budgets / lane widths / global batches
        # must not touch the shared sampler's live schedule, the live
        # tier, or the live pool's lane split.
        self.loader.with_params(self.loader.params.replace(
            num_workers=nworker, prefetch_factor=nprefetch,
            device_prefetch=self.device_prefetch))
        kw = {} if cache_budget_bytes is None \
            else {"cache_budget_bytes": cache_budget_bytes}
        if slow_lane_workers is not None:
            kw["slow_lane_workers"] = slow_lane_workers
        if global_batch is not None:
            kw["global_batch"] = global_batch
        return self.loader.measure_transfer_time(
            num_batches, epoch=epoch, to_device=self.to_device,
            locality_chunk=locality_chunk, **kw)


class SimulatorEvaluator:
    """Queries the virtual-time model (paper-table benchmarks, fleet sim)."""

    def __init__(self, sim: LoaderSimulator, *, batch_size: int,
                 device_prefetch: int = 2, device_ram: Optional[float] = None,
                 num_batches_cap: Optional[int] = None, host_count: int = 1):
        self.sim = sim
        self.batch_size = batch_size
        self.device_prefetch = device_prefetch
        self.device_ram = device_ram
        self.num_batches_cap = num_batches_cap
        # geometry-axis pricing: a candidate GLOBAL batch divides over
        # this many lockstep hosts before it hits one host's loader
        self.host_count = max(1, host_count)
        self.calls = 0

    def __call__(self, nworker: int, nprefetch: int, *, num_batches: int = 16,
                 epoch: int = 0,
                 locality_chunk: Optional[int] = None,
                 cache_budget_bytes: Optional[int] = None,
                 slow_lane_workers: Optional[int] = None,
                 global_batch: Optional[int] = None) -> TransferStats:
        self.calls += 1
        if self.num_batches_cap is not None:
            num_batches = min(num_batches, self.num_batches_cap)
        local = self.batch_size if not global_batch \
            else max(1, int(round(global_batch / self.host_count)))
        r = self.sim.simulate(
            batch_size=local, num_batches=num_batches,
            nworker=nworker, nprefetch=nprefetch, epoch=epoch,
            device_prefetch=self.device_prefetch, device_ram=self.device_ram,
            locality_chunk=locality_chunk or 0,
            cache_budget_bytes=cache_budget_bytes or 0,
            slow_lane_workers=slow_lane_workers or 0)
        return TransferStats(r.seconds, num_batches,
                             int(num_batches * self.sim.batch_bytes(local)),
                             peak_loader_bytes=int(r.peak_bytes))

    def epoch_seconds(self, nworker: int, nprefetch: int, *,
                      epoch: int = 0) -> float:
        """Full-epoch transfer time (paper Table 1b reports whole epochs)."""
        n = self.sim.sp.num_items // self.batch_size
        try:
            return self(nworker, nprefetch, num_batches=n,
                        epoch=epoch).seconds
        except MemoryOverflow:
            return math.inf
