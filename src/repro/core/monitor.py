"""Memory monitoring / overflow guard (the "Monitoring" box in Fig. 1).

The paper's Algorithm 1 breaks the inner prefetch loop when "Memory Overflow
occur[s]".  We guard two ways:

* an *estimate*: outstanding-batch bytes (worker queues + device prefetch
  buffers) against a budget — cheap, deterministic, works in virtual time;
* a *real* RSS watermark read from /proc/self/statm — catches actual
  blow-ups during wall-clock measurement runs.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Optional


class MemoryOverflow(RuntimeError):
    """Raised when a (nWorker, nPrefetch) trial exceeds the memory budget."""


def process_rss_bytes() -> int:
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        return pages * os.sysconf("SC_PAGE_SIZE")
    except (OSError, ValueError, IndexError):  # pragma: no cover
        return 0


def host_ram_bytes() -> int:
    try:
        return os.sysconf("SC_PAGE_SIZE") * os.sysconf("SC_PHYS_PAGES")
    except (ValueError, OSError):  # pragma: no cover
        return 64 << 30


@dataclasses.dataclass
class MemoryBudget:
    """Budget for loader-owned memory (not the whole process)."""
    loader_bytes: int
    host_ram: int = dataclasses.field(default_factory=host_ram_bytes)
    rss_fraction: float = 0.92     # real watermark: RSS vs host RAM


class MemoryMonitor:
    def __init__(self, budget: Optional[MemoryBudget] = None,
                 check_rss: bool = False):
        self.budget = budget
        self.check_rss = check_rss
        self._lock = threading.Lock()
        self._outstanding = 0
        self.peak = 0
        self.overflowed = False

    def reserve(self, nbytes: int) -> None:
        with self._lock:
            self._outstanding += nbytes
            self.peak = max(self.peak, self._outstanding)
            if (self.budget is not None
                    and self._outstanding > self.budget.loader_bytes):
                self.overflowed = True
                raise MemoryOverflow(
                    f"loader footprint {self._outstanding/2**20:.1f}MiB > "
                    f"budget {self.budget.loader_bytes/2**20:.1f}MiB")
        if self.check_rss and self.budget is not None:
            rss = process_rss_bytes()
            if rss > self.budget.rss_fraction * self.budget.host_ram:
                self.overflowed = True
                raise MemoryOverflow(
                    f"RSS {rss/2**30:.2f}GiB > "
                    f"{self.budget.rss_fraction:.0%} of host RAM")

    def release(self, nbytes: int) -> None:
        with self._lock:
            self._outstanding -= nbytes

    @property
    def outstanding(self) -> int:
        return self._outstanding


def estimate_loader_footprint(batch_bytes: float, num_workers: int,
                              prefetch_factor: int,
                              device_prefetch: int = 2) -> float:
    """Static footprint estimate used by the simulator and the overflow
    pre-check: queued batches + per-worker in-flight batch + device buffers."""
    queued = max(1, num_workers) * max(1, prefetch_factor) * batch_bytes
    in_flight = max(1, num_workers) * batch_bytes
    return queued + in_flight + device_prefetch * batch_bytes
