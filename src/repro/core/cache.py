"""DPT result cache (paper §5: "parameters deduced by DPT can be used for
datasets with similar characteristics" on the same machine).

Keyed by (machine fingerprint, dataset fingerprint, batch-size bucket,
epoch class).  Dataset fingerprints bucket item size / decode cost in
half-octave bins, so e.g. two ~100KB-JPEG folders share tuned parameters
while 80x80 and 640x640 resizes do not.
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Optional, Tuple

from repro.core.dpt import DPTResult


def _batch_bucket(batch_size: int) -> int:
    return int(round(math.log2(max(batch_size, 1))))


# the beyond-paper axes, in tuple order: (axis name, DPTResult/Trial field).
# Every axis follows the same lifecycle — an entry records the winning
# value plus a "<axis>_searched" flag (did the sweep actually price the
# axis?), reads can require a searched axis, and an axis-blind refinement
# must never clobber a searched value back to 0.  One table instead of a
# copy of that logic per axis.
_AXES: Tuple[Tuple[str, str], ...] = (
    ("locality", "locality_chunk"),
    ("cache", "cache_budget_bytes"),
    ("slow_lane", "slow_lane_workers"),
    ("geometry", "global_batch"),
)


class DPTCache:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._store: dict = {}
        if path and os.path.exists(path):
            with open(path) as f:
                self._store = json.load(f)

    def _key(self, machine_fp: str, dataset_fp: str, batch_size: int,
             epoch: int) -> str:
        epoch_class = "cold" if epoch == 0 else "warm"
        return f"{machine_fp}|{dataset_fp}|b{_batch_bucket(batch_size)}|{epoch_class}"

    def get(self, machine_fp: str, dataset_fp: str, batch_size: int,
            epoch: int = 0) -> Optional[Tuple[int, int]]:
        with self._lock:
            v = self._store.get(self._key(machine_fp, dataset_fp,
                                          batch_size, epoch))
        return (v["nworker"], v["nprefetch"]) if v else None

    def get_params(self, machine_fp: str, dataset_fp: str, batch_size: int,
                   epoch: int = 0, *, require_locality: bool = False,
                   require_cache: bool = False, with_cache: bool = False,
                   require_slow_lane: bool = False,
                   with_slow_lane: bool = False,
                   require_geometry: bool = False,
                   with_geometry: bool = False
                   ) -> Optional[Tuple[int, ...]]:
        """Like ``get`` but with the locality axis: (nworker, nprefetch,
        locality_chunk).  Entries written before the axis existed read
        back as locality 0 (random order).  ``require_locality=True``
        treats entries whose search never swept the axis as misses — a
        run that newly enables the axis must not be satisfied by a stale
        two-axis result.

        Every later axis is opt-in, so the 3-tuple contract above is
        unchanged for existing callers; ``with_<axis>=True`` appends the
        axis value in ``_AXES`` order (cache budget, slow-lane workers,
        geometry global batch) and ``require_<axis>=True`` treats entries
        whose search never swept that axis as misses — the same staleness
        rule applied uniformly through the axis table."""
        require = {"locality": require_locality, "cache": require_cache,
                   "slow_lane": require_slow_lane,
                   "geometry": require_geometry}
        append = {"cache": with_cache, "slow_lane": with_slow_lane,
                  "geometry": with_geometry}
        with self._lock:
            v = self._store.get(self._key(machine_fp, dataset_fp,
                                          batch_size, epoch))
        if not v:
            return None
        for axis, _field in _AXES:
            if require[axis] and not v.get(f"{axis}_searched", False):
                return None
        out = (v["nworker"], v["nprefetch"],
               int(v.get("locality_chunk", 0)))
        for axis, field in _AXES:
            if append.get(axis):
                out = out + (int(v.get(field, 0)),)
        return out

    def put(self, machine_fp: str, dataset_fp: str, batch_size: int,
            result: DPTResult, epoch: int = 0) -> None:
        key = self._key(machine_fp, dataset_fp, batch_size, epoch)
        entry = {
            "nworker": result.nworker,
            "nprefetch": result.nprefetch,
            "optimal_time": result.optimal_time,
        }
        for axis, field in _AXES:
            entry[field] = getattr(result, field, 0)
            # did the sweep actually price the axis?  any non-zero value
            # among the trials means candidates were measured (a searched
            # axis always includes one)
            entry[f"{axis}_searched"] = any(
                getattr(t, field, 0) for t in result.trials)
        with self._lock:
            prev = self._store.get(key)
            for axis, field in _AXES:
                if (not entry[f"{axis}_searched"] and prev
                        and prev.get(f"{axis}_searched")):
                    # an axis-blind refinement (e.g. an online 2-axis
                    # retune) was measured AT the live value: it refines
                    # (nworker, nprefetch) without invalidating the
                    # searched axis — keep it instead of clobbering to 0
                    entry[field] = prev.get(field, 0)
                    entry[f"{axis}_searched"] = True
            self._store[key] = entry
            if self.path:
                tmp = self.path + ".tmp"
                with open(tmp, "w") as f:
                    json.dump(self._store, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)

    def __len__(self):
        return len(self._store)
