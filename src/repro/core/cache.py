"""DPT result cache (paper §5: "parameters deduced by DPT can be used for
datasets with similar characteristics" on the same machine).

Keyed by (machine fingerprint, dataset fingerprint, batch-size bucket,
epoch class).  Dataset fingerprints bucket item size / decode cost in
half-octave bins, so e.g. two ~100KB-JPEG folders share tuned parameters
while 80x80 and 640x640 resizes do not.
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Optional, Tuple

from repro.core.dpt import DPTResult


def _batch_bucket(batch_size: int) -> int:
    return int(round(math.log2(max(batch_size, 1))))


class DPTCache:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._store: dict = {}
        if path and os.path.exists(path):
            with open(path) as f:
                self._store = json.load(f)

    def _key(self, machine_fp: str, dataset_fp: str, batch_size: int,
             epoch: int) -> str:
        epoch_class = "cold" if epoch == 0 else "warm"
        return f"{machine_fp}|{dataset_fp}|b{_batch_bucket(batch_size)}|{epoch_class}"

    def get(self, machine_fp: str, dataset_fp: str, batch_size: int,
            epoch: int = 0) -> Optional[Tuple[int, int]]:
        with self._lock:
            v = self._store.get(self._key(machine_fp, dataset_fp,
                                          batch_size, epoch))
        return (v["nworker"], v["nprefetch"]) if v else None

    def get_params(self, machine_fp: str, dataset_fp: str, batch_size: int,
                   epoch: int = 0, *, require_locality: bool = False,
                   require_cache: bool = False, with_cache: bool = False,
                   require_slow_lane: bool = False,
                   with_slow_lane: bool = False
                   ) -> Optional[Tuple[int, ...]]:
        """Like ``get`` but with the locality axis: (nworker, nprefetch,
        locality_chunk).  Entries written before the axis existed read
        back as locality 0 (random order).  ``require_locality=True``
        treats entries whose search never swept the axis as misses — a
        run that newly enables the axis must not be satisfied by a stale
        two-axis result.

        The cache axis (DESIGN.md §7) is opt-in, so the 3-tuple contract
        above is unchanged for existing callers: ``with_cache=True``
        appends ``cache_budget_bytes`` as a fourth element;
        ``require_cache=True`` treats entries whose search never swept
        the budget axis as misses (same staleness rule as locality).
        The dual-lane axis (DESIGN.md §9) follows the same pattern:
        ``with_slow_lane=True`` appends ``slow_lane_workers`` and
        ``require_slow_lane=True`` treats lane-blind entries as misses."""
        with self._lock:
            v = self._store.get(self._key(machine_fp, dataset_fp,
                                          batch_size, epoch))
        if not v:
            return None
        if require_locality and not v.get("locality_searched", False):
            return None
        if require_cache and not v.get("cache_searched", False):
            return None
        if require_slow_lane and not v.get("slow_lane_searched", False):
            return None
        out = (v["nworker"], v["nprefetch"],
               int(v.get("locality_chunk", 0)))
        if with_cache:
            out = out + (int(v.get("cache_budget_bytes", 0)),)
        if with_slow_lane:
            out = out + (int(v.get("slow_lane_workers", 0)),)
        return out

    def put(self, machine_fp: str, dataset_fp: str, batch_size: int,
            result: DPTResult, epoch: int = 0) -> None:
        key = self._key(machine_fp, dataset_fp, batch_size, epoch)
        entry = {
            "nworker": result.nworker,
            "nprefetch": result.nprefetch,
            "optimal_time": result.optimal_time,
            "locality_chunk": getattr(result, "locality_chunk", 0),
            # did the sweep actually price the axis?  any non-zero chunk
            # among the trials means candidate chunks were measured (a
            # searched axis always includes one)
            "locality_searched": any(
                getattr(t, "locality_chunk", 0) for t in result.trials),
            "cache_budget_bytes": getattr(result, "cache_budget_bytes", 0),
            "cache_searched": any(
                getattr(t, "cache_budget_bytes", 0) for t in result.trials),
            "slow_lane_workers": getattr(result, "slow_lane_workers", 0),
            "slow_lane_searched": any(
                getattr(t, "slow_lane_workers", 0) for t in result.trials),
        }
        with self._lock:
            prev = self._store.get(key)
            if (not entry["locality_searched"] and prev
                    and prev.get("locality_searched")):
                # a locality-blind refinement (e.g. an online 2-axis
                # retune) was measured AT the live chunk: it refines
                # (nworker, nprefetch) without invalidating the searched
                # locality — keep it instead of clobbering it to 0
                entry["locality_chunk"] = prev.get("locality_chunk", 0)
                entry["locality_searched"] = True
            if (not entry["cache_searched"] and prev
                    and prev.get("cache_searched")):
                # same protection for the cache axis: a budget-blind
                # refinement must not clobber a searched budget to 0
                entry["cache_budget_bytes"] = prev.get(
                    "cache_budget_bytes", 0)
                entry["cache_searched"] = True
            if (not entry["slow_lane_searched"] and prev
                    and prev.get("slow_lane_searched")):
                # and for the dual-lane axis: a lane-blind refinement
                # must not clobber a searched lane width to 0
                entry["slow_lane_workers"] = prev.get(
                    "slow_lane_workers", 0)
                entry["slow_lane_searched"] = True
            self._store[key] = entry
            if self.path:
                tmp = self.path + ".tmp"
                with open(tmp, "w") as f:
                    json.dump(self._store, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)

    def __len__(self):
        return len(self._store)
