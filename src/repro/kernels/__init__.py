from repro.kernels import ops  # noqa: F401
from repro.kernels import ref  # noqa: F401
