"""Mamba2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

TPU adaptation of the SSD algorithm (paper: arXiv 2405.21060, GPU Triton
kernels): the sequence is split into chunks; within a chunk the dual
(quadratic, MXU-friendly) form computes the causal-decay-masked C B^T x
contribution as two small matmuls, and the recurrent inter-chunk state is
carried in VMEM scratch across the innermost grid dimension (TPU grids are
sequential, so the (P x N) state simply persists between chunk steps — the
TPU analogue of the GPU kernel's cross-CTA state passing).

Grid: (batch, heads, num_chunks); the state scratch is reset at chunk 0.
Oracles: ``ref.ssd_chunked`` (same chunked math) and ``ref.ssd_naive``
(sequential recurrence ground truth).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                               getattr(pltpu, "TPUCompilerParams", None))
except ImportError:  # pragma: no cover
    pltpu = None
    _COMPILER_PARAMS = None


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_scr):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (c, p)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (c,)
    a = a_ref[0].astype(jnp.float32)           # scalar ()
    bm = b_ref[0, 0].astype(jnp.float32)       # (c, n)
    cm = c_ref[0, 0].astype(jnp.float32)       # (c, n)

    da = dt * a                                # (c,)
    cum = jnp.cumsum(da)                       # inclusive
    total = cum[-1]
    c_len = x.shape[0]

    li = cum[:, None]
    lj = cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (c_len, c_len), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c_len, c_len), 1)
    L = jnp.where(jj <= ii, jnp.exp(li - lj), 0.0)          # (c, c)

    xdt = x * dt[:, None]                                   # (c, p)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y_intra = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    state = state_scr[...]                                  # (p, n)
    c_exp = cm * jnp.exp(cum)[:, None]                      # (c, n)
    y_inter = jax.lax.dot_general(c_exp, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    tail = jnp.exp(total - cum)                             # (c,)
    new_state = jax.lax.dot_general(xdt, bm * tail[:, None],
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(total) + new_state

    o_ref[0, 0] = (y_intra + y_inter).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = False):
    """Chunked SSD scan.

    x: (b, s, h, p); dt: (b, s, h); A: (h,); B, C: (b, s, g, n), h % g == 0.
    Returns y: (b, s, h, p).  Sequence length must be a multiple of ``chunk``
    (the wrapper in ops.py pads).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    group = h // g

    xt = jnp.moveaxis(x, 2, 1)                 # (b, h, s, p)
    dtt = jnp.moveaxis(dt, 2, 1)               # (b, h, s)
    bt = jnp.moveaxis(B, 2, 1)                 # (b, g, s, n)
    ct = jnp.moveaxis(C, 2, 1)

    params = {}
    if _COMPILER_PARAMS is not None and not interpret:
        params["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        _kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda ib, ih, ic: (ib, ih, ic)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda ib, ih, ic: (ib, ih // group, ic, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda ib, ih, ic: (ib, ih // group, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda ib, ih, ic: (ib, ih, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        **params,
    )(xt, dtt, A, bt, ct)
    return jnp.moveaxis(out, 1, 2)
