"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references the kernel tests assert against
(``interpret=True`` kernel output vs these, allclose over shape/dtype sweeps)
and the CPU execution path of ``ops.py`` (this container has no TPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def attention_mask(q_pos, kv_pos, *, causal: bool, window: int,
                   kv_valid: Optional[jnp.ndarray] = None,
                   num_sink: int = 0):
    """Boolean mask (B, S, T): True = attend.

    q_pos: (B,S) absolute positions of queries; kv_pos: (B,T) of keys
    (negative = invalid/ring slot not yet written); kv_valid: (B,) number of
    valid cache slots (decode), or None.  num_sink: positions < num_sink stay
    visible through sliding windows (attention sinks / hymba meta tokens).
    """
    m = kv_pos[:, None, :] >= 0
    if causal:
        m &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window > 0:
        in_window = q_pos[:, :, None] - kv_pos[:, None, :] < window
        if num_sink > 0:
            in_window |= kv_pos[:, None, :] < num_sink
        m &= in_window
    if kv_valid is not None:
        m &= kv_pos[:, None, :] < kv_valid[:, None, None]
    return m


def mha(q, k, v, *, causal: bool = True, window: int = 0,
        q_pos=None, kv_pos=None, kv_valid=None, softcap: float = 0.0,
        scale: Optional[float] = None, num_sink: int = 0):
    """Multi-head attention oracle with GQA.

    q: (B,S,H,D); k, v: (B,T,K,D) with H % K == 0.  fp32 softmax.
    """
    B, S, H, D = q.shape
    _, T, K, _ = k.shape
    assert H % K == 0, (H, K)
    G = H // K
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    scale = scale if scale is not None else D ** -0.5

    qg = q.reshape(B, S, K, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = attention_mask(q_pos, kv_pos, causal=causal, window=window,
                          kv_valid=kv_valid, num_sink=num_sink)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (e.g. padding) -> zeros, not NaN
    any_valid = mask.any(-1)[:, None, None, :]
    probs = jnp.where(any_valid[..., None], probs, 0.0)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def mha_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                num_sink: int = 0, scale: Optional[float] = None,
                block_q: int = 512):
    """Memory-efficient exact attention: lax.scan over query blocks with a
    checkpointed body, so peak memory is O(block_q * T) instead of O(S * T).

    This is the XLA-path analogue of the Pallas flash kernel (same math,
    same masking) used for long-sequence train/prefill cells on backends
    where the Pallas kernel can't lower (e.g. the CPU dry-run).
    """
    B, S, H, D = q.shape
    _, T, K, _ = k.shape
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, S)
    pad = (-S) % block_q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    qb = q.reshape(B, nq, block_q, H, D)
    qb = jnp.moveaxis(qb, 1, 0)                       # (nq, B, bq, H, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kv_pos = jnp.arange(T)

    def body(_, args):
        iq, qblk = args
        qf = qblk.reshape(B, block_q, K, G, D).astype(jnp.float32)
        logits = jnp.einsum("bskgd,btkd->bkgst", qf, kf) * scale
        q_pos = iq * block_q + jnp.arange(block_q)
        m = jnp.ones((block_q, T), bool)
        if causal:
            m &= kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            in_w = q_pos[:, None] - kv_pos[None, :] < window
            if num_sink > 0:
                in_w |= kv_pos[None, :] < num_sink
            m &= in_w
        logits = jnp.where(m[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        any_valid = m.any(-1)[None, None, None]
        probs = jnp.where(any_valid[..., None], probs, 0.0)
        ob = jnp.einsum("bkgst,btkd->bskgd", probs, vf)
        return None, ob.reshape(B, block_q, H, D).astype(q.dtype)

    body = jax.checkpoint(body, prevent_cse=False)
    _, out = jax.lax.scan(body, None, (jnp.arange(nq), qb))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * block_q, H, D)
    return out[:, :S]


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# mamba2 SSD (state-space duality) chunked scan
# --------------------------------------------------------------------------
def ssd_naive(x, dt, A, B, C, *, initial_state=None):
    """Sequential recurrence oracle (the ground truth the chunked forms match).

    x: (b, s, h, p); dt: (b, s, h); A: (h,) (negative values);
    B, C: (b, s, g, n) with h % g == 0.  Returns (y, final_state) with
    y: (b, s, h, p), state: (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)   # (b,s,h,n)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, None, :])               # (b,s,h)

    def step(state, inp):
        xt, bt, ct, dct, dtt = inp                        # (b,h,p),(b,h,n),...
        state = state * dct[..., None, None] \
            + jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(Bh, 1, 0),
          jnp.moveaxis(Ch, 1, 0), jnp.moveaxis(decay, 1, 0),
          jnp.moveaxis(dtf, 1, 0))
    state, ys = jax.lax.scan(step, initial_state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_chunked(x, dt, A, B, C, *, chunk: int = 64, initial_state=None):
    """Chunked SSD oracle — the parallel form the Pallas kernel implements.

    Splits the sequence into chunks; computes the intra-chunk quadratic term
    and carries inter-chunk state with a scan.  Mathematically identical to
    ``ssd_naive`` (up to fp error).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32).reshape(b, nc, chunk, h, n)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32).reshape(b, nc, chunk, h, n)

    da = dtf * A[None, None, None, :]                      # (b,nc,c,h)
    cum = jnp.cumsum(da, axis=2)                           # inclusive cumsum
    total = cum[:, :, -1:, :]                              # (b,nc,1,h)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i  (decay j->i)
    li = cum[:, :, :, None, :]                             # (b,nc,c,1,h)
    lj = cum[:, :, None, :, :]                             # (b,nc,1,c,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(li - lj), 0.0)

    xdt = xf * dtf[..., None]
    scores = jnp.einsum("bzihn,bzjhn->bzijh", Ch, Bh) * L  # (b,nc,c,c,h)
    y_intra = jnp.einsum("bzijh,bzjhp->bzihp", scores, xdt)

    # chunk states: sum_j exp(total - cum_j) B_j x_j dt_j
    tail = jnp.exp(total - cum)                            # (b,nc,c,h)
    chunk_state = jnp.einsum("bzjhn,bzjhp->bzhpn", Bh * tail[..., None], xdt)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(total[:, :, 0, :])               # (b,nc,h)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        cs, cd = inp                                       # (b,h,p,n),(b,h)
        prev = state
        state = state * cd[..., None, None] + cs
        return state, prev

    states_in = (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    final_state, prev_states = jax.lax.scan(step, initial_state, states_in)
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (b,nc,h,p,n)

    # inter-chunk contribution: C_i exp(cum_i) @ prev_state
    y_inter = jnp.einsum("bzihn,bzhpn->bzihp", Ch * jnp.exp(cum)[..., None],
                         prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_step(state, x, dt, A, B, C):
    """Single-token SSD recurrence for decode.

    state: (b,h,p,n); x: (b,h,p); dt: (b,h); B, C: (b,g,n).
    """
    h = x.shape[1]
    g = B.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, :])
    state = state * decay[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x.astype(jnp.float32) * dtf[..., None], Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y.astype(x.dtype), state
