"""Blockwise (flash) attention Pallas TPU kernel.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
* The grid's innermost dimension is executed sequentially on a TPU core, so
  the online-softmax running state (m, l, acc) lives in VMEM scratch that
  persists across KV-block grid steps — no shared-memory/warp machinery.
* Block shapes are MXU/VPU aligned: block_q x head_dim and block_k x head_dim
  tiles with head_dim padded to a multiple of 128 by the wrapper.
* GQA is native: the kv-head index map folds the query-head -> kv-head
  mapping, so grouped heads never materialize repeated K/V.
* Causal + sliding-window masking is positional; fully-masked KV blocks are
  skipped via ``pl.when`` (halves work for causal, much more for SWA).

Validated in interpret mode against ``ref.mha`` (see tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params: name moved across jax versions
    from jax.experimental.pallas import tpu as pltpu
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                               getattr(pltpu, "TPUCompilerParams", None))
except ImportError:  # pragma: no cover
    pltpu = None
    _COMPILER_PARAMS = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, num_kv_blocks: int, q_len: int, kv_len: int,
            q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q + q_offset          # absolute position of q row 0
    kv_start = ik * block_k

    run = jnp.bool_(True)
    if causal:
        run &= kv_start <= q_start + block_q - 1
    if window > 0:
        run &= kv_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                     # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                     # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                     # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kv_pos < kv_len                                  # pad keys
        if causal:
            mask &= kv_pos <= q_pos
        if window > 0:
            mask &= q_pos - kv_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _pad_to(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "q_offset",
                     "interpret", "scale"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, scale: Optional[float] = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q: (B,S,H,D); k, v: (B,T,K,D), H % K == 0.  Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    _, T, K, _ = k.shape
    assert H % K == 0, (H, K)
    scale = float(scale if scale is not None else D ** -0.5)
    block_q = min(block_q, max(S, 8))
    block_k = min(block_k, max(T, 8))

    # (B,H,S,D) layout; pad seq dims to block multiples, head_dim to 128.
    qt = _pad_to(_pad_to(jnp.moveaxis(q, 2, 1), 2, block_q), 3, 128)
    kt = _pad_to(_pad_to(jnp.moveaxis(k, 2, 1), 2, block_k), 3, 128)
    vt = _pad_to(_pad_to(jnp.moveaxis(v, 2, 1), 2, block_k), 3, 128)
    Sp, Tp, Dp = qt.shape[2], kt.shape[2], qt.shape[3]
    nq, nk = Sp // block_q, Tp // block_k
    group = H // K

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk, q_len=S, kv_len=T,
        q_offset=q_offset)

    params = {}
    if _COMPILER_PARAMS is not None and not interpret:
        params["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dp), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, Dp),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, Dp),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dp),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max m
            pltpu.VMEM((block_q,), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, Dp), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
        **params,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :S, :D], 1, 2)
