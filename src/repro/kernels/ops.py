"""Public kernel API with backend dispatch.

Models call these wrappers, never the kernels directly:

* on TPU -> Pallas kernels (``flash_attention``, ``rmsnorm``, ``ssd_scan``),
* on CPU (this container, smoke tests, dry-run) -> pure-jnp oracles from
  ``ref.py`` (identical math; XLA fuses them well enough for correctness
  work),
* ``REPRO_KERNEL_IMPL`` env var forces ``ref`` / ``pallas`` /
  ``pallas_interpret`` (the last runs the kernel bodies in Python on CPU —
  that is how the test suite validates the TPU kernels here).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _impl() -> str:
    forced = os.environ.get("REPRO_KERNEL_IMPL", "")
    if forced:
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_pos=None, kv_pos=None, kv_valid=None, softcap: float = 0.0,
              q_offset: int = 0, scale: Optional[float] = None,
              num_sink: int = 0, block_q: int = 256, block_k: int = 256):
    """Multi-head (GQA) attention.  q: (B,S,H,D); k, v: (B,T,K,D)."""
    impl = _impl()
    ragged = q_pos is not None or kv_pos is not None or kv_valid is not None \
        or softcap > 0.0 or num_sink > 0
    if impl.startswith("pallas") and not ragged:
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            scale=scale, block_q=block_q, block_k=block_k,
            interpret=impl == "pallas_interpret")
    if q_offset and q_pos is None:
        B, S = q.shape[:2]
        q_pos = jnp.broadcast_to(q_offset + jnp.arange(S)[None, :], (B, S))
    # long full-sequence paths use the chunked (flash-equivalent) oracle so
    # peak memory stays O(block * T) — required for the 32k prefill cells.
    simple = (q_pos is None and kv_pos is None and kv_valid is None
              and softcap == 0.0 and q_offset == 0)
    if simple and q.shape[1] >= 1024:
        return _ref.mha_chunked(q, k, v, causal=causal, window=window,
                                num_sink=num_sink, scale=scale)
    return _ref.mha(q, k, v, causal=causal, window=window, q_pos=q_pos,
                    kv_pos=kv_pos, kv_valid=kv_valid, softcap=softcap,
                    scale=scale, num_sink=num_sink)


def rmsnorm(x, scale, *, eps: float = 1e-6):
    impl = _impl()
    if impl.startswith("pallas"):
        return _rn.rmsnorm(x, scale, eps=eps,
                           interpret=impl == "pallas_interpret")
    return _ref.rmsnorm(x, scale, eps)


def rmsnorm_residual(x, residual, scale, *, eps: float = 1e-6):
    """Returns (normed, new_residual) for fused residual-add + norm."""
    impl = _impl()
    if impl.startswith("pallas"):
        return _rn.rmsnorm_residual(x, residual, scale, eps=eps,
                                    interpret=impl == "pallas_interpret")
    new_res = x + residual
    return _ref.rmsnorm(new_res, scale, eps), new_res


def ssd(x, dt, A, B, C, *, chunk: int = 256):
    """Chunked SSD scan (training/prefill).  See ssd_scan.py for shapes."""
    impl = _impl()
    s = x.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        widths = [(0, 0)] * 4
        widths[1] = (0, pad)
        x = jnp.pad(x, widths)
        B = jnp.pad(B, widths)
        C = jnp.pad(C, widths)
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
    if impl.startswith("pallas"):
        y = _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk,
                          interpret=impl == "pallas_interpret")
    else:
        y, _ = _ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    return y[:, :s] if pad else y


def ssd_prefill(x, dt, A, B, C, *, chunk: int = 256):
    """SSD scan that also returns the final state (for prefill -> decode).

    Always the jnp chunked path (state output needed)."""
    s = x.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        widths = [(0, 0)] * 4
        widths[1] = (0, pad)
        # pad dt with zeros -> exp(0 * A) = 1, no state decay from padding,
        # and zero dt zeroes the padded tokens' state contribution.
        x = jnp.pad(x, widths)
        B = jnp.pad(B, widths)
        C = jnp.pad(C, widths)
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
    y, state = _ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    return (y[:, :s] if pad else y), state


def ssd_step(state, x, dt, A, B, C):
    """Single-token SSD recurrence (decode); memory-bound, jnp path."""
    return _ref.ssd_step(state, x, dt, A, B, C)
