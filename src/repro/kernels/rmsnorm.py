"""Fused RMSNorm Pallas TPU kernel (optionally fused residual add).

One VMEM round-trip instead of three (square/mean, rsqrt-scale, residual):
rows are tiled (block_rows x d) so the working set stays in VMEM; the
reduction and scale run in fp32 on the VPU and the result is written back in
the input dtype.  Oracle: ``ref.rmsnorm``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                               getattr(pltpu, "TPUCompilerParams", None))
except ImportError:  # pragma: no cover
    pltpu = None
    _COMPILER_PARAMS = None


def _kernel(x_ref, scale_ref, o_ref, *, eps: float, d: int):
    x = x_ref[...].astype(jnp.float32)
    # padded tail columns contribute zeros; divide by true d
    var = jnp.sum(x * x, axis=-1, keepdims=True) / d
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _kernel_residual(x_ref, res_ref, scale_ref, o_ref, newres_ref, *,
                     eps: float, d: int):
    x = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    newres_ref[...] = x.astype(newres_ref.dtype)
    var = jnp.sum(x * x, axis=-1, keepdims=True) / d
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., d); scale: (d,).  Returns rmsnorm(x) * scale."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, max(rows, 1))
    pad_rows = (-rows) % block_rows
    pad_d = (-d) % 128
    if pad_rows or pad_d:
        x2 = jnp.pad(x2, ((0, pad_rows), (0, pad_d)))
    scale_p = jnp.pad(scale, (0, pad_d)) if pad_d else scale
    R, Dp = x2.shape
    grid = (R // block_rows,)

    params = {}
    if _COMPILER_PARAMS is not None and not interpret:
        params["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("parallel",))

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, Dp), lambda i: (i, 0)),
            pl.BlockSpec((Dp,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, Dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, Dp), x.dtype),
        interpret=interpret,
        **params,
    )(x2, scale_p)
    return out[:rows, :d].reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_residual(x, residual, scale, *, eps: float = 1e-6,
                     block_rows: int = 256, interpret: bool = False):
    """Fused (x + residual) -> new_residual, rmsnorm(new_residual) * scale.

    Returns (normed, new_residual)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    r2 = residual.reshape(rows, d)
    block_rows = min(block_rows, max(rows, 1))
    pad_rows = (-rows) % block_rows
    pad_d = (-d) % 128
    if pad_rows or pad_d:
        x2 = jnp.pad(x2, ((0, pad_rows), (0, pad_d)))
        r2 = jnp.pad(r2, ((0, pad_rows), (0, pad_d)))
    scale_p = jnp.pad(scale, (0, pad_d)) if pad_d else scale
    R, Dp = x2.shape

    params = {}
    if _COMPILER_PARAMS is not None and not interpret:
        params["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("parallel",))

    normed, newres = pl.pallas_call(
        functools.partial(_kernel_residual, eps=eps, d=d),
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, Dp), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Dp), lambda i: (i, 0)),
            pl.BlockSpec((Dp,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, Dp), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, Dp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, Dp), x.dtype),
            jax.ShapeDtypeStruct((R, Dp), x.dtype),
        ],
        interpret=interpret,
        **params,
    )(x2, r2, scale_p)
    return (normed[:rows, :d].reshape(orig_shape),
            newres[:rows, :d].reshape(orig_shape))
