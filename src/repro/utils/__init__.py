from repro.utils.tree import (  # noqa: F401
    tree_bytes,
    tree_count,
    tree_map_with_path_str,
    flatten_with_names,
)
from repro.utils.fingerprint import dataset_fingerprint, machine_fingerprint  # noqa: F401
