"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of scalar elements across all leaves."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path_str(fn, tree):
    """Like tree_map but fn receives (path_string, leaf)."""
    return jax.tree_util.tree_map_with_path(lambda p, l: fn(_path_str(p), l), tree)


def flatten_with_names(tree):
    """Return [(path_string, leaf), ...] in tree order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), l) for p, l in flat]
