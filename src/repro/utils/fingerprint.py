"""Fingerprints for DPT result reuse (paper §5: tuned parameters "may be reused
on the same machine upon loading data sets that have similar characteristics").

A dataset fingerprint captures the characteristics that drive loader behaviour
(item size distribution, decode cost class, count); a machine fingerprint
captures the host resources that bound the search space (cores, RAM, device
count).  DPT's cache is keyed on both.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
from dataclasses import asdict, is_dataclass


def _stable_hash(obj) -> str:
    if is_dataclass(obj) and not isinstance(obj, type):
        obj = asdict(obj)
    blob = json.dumps(obj, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def dataset_fingerprint(*, item_bytes: float, decode_cost: float,
                        num_items: int, item_bytes_std: float = 0.0,
                        bucket: bool = True) -> str:
    """Bucketed fingerprint: similar datasets hash identically.

    Bucketing uses order-of-magnitude bins so that e.g. two image folders with
    ~100KB JPEGs share a fingerprint while 80x80 vs 640x640 resolutions do not.
    """
    import math

    def _bin(x: float) -> float:
        if not bucket:
            return x
        if x <= 0:
            return 0.0
        return round(math.log2(max(x, 1e-12)) * 2) / 2  # half-octave bins

    return _stable_hash({
        "item_bytes": _bin(item_bytes),
        "decode_cost": _bin(decode_cost),
        "num_items": _bin(float(num_items)),
        "item_bytes_std": _bin(item_bytes_std),
    })


def machine_fingerprint(*, cpu_count: int | None = None,
                        device_count: int | None = None,
                        host_ram_bytes: int | None = None) -> str:
    if cpu_count is None:
        cpu_count = os.cpu_count() or 1
    if device_count is None:
        try:
            import jax

            device_count = jax.local_device_count()
        except Exception:  # pragma: no cover - jax always present here
            device_count = 1
    if host_ram_bytes is None:
        try:
            host_ram_bytes = os.sysconf("SC_PAGE_SIZE") * os.sysconf("SC_PHYS_PAGES")
        except (ValueError, OSError):  # pragma: no cover
            host_ram_bytes = 0
    return _stable_hash({
        "cpu": cpu_count,
        "devices": device_count,
        "ram_gb": round(host_ram_bytes / 2**30),
        "machine": platform.machine(),
    })
