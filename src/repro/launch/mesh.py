"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Topology: TPU v5e pods, 16x16 = 256 chips per pod; multi-pod = 2 pods (512
chips) with a leading "pod" axis (DCI-connected; pure data parallelism
crosses pods, model parallelism never does).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Smoke-scale mesh from whatever devices exist (tests: 1 or 8 CPU
    devices)."""
    n = jax.device_count()
    assert n % model_axis == 0, (n, model_axis)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def mesh_chips(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
