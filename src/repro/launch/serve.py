"""Serving launcher: ``python -m repro.launch.serve --arch qwen2-0.5b --reduced``

Builds the model, spins up the batching frontend and runs a synthetic
request workload through prefill + jit'd decode (greedy or sampled),
reporting tokens/s and batch formation stats.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.serve.engine import BatchingFrontend, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, max_batch=args.max_batch,
                         max_len=args.prompt_len + args.max_new + 8,
                         temperature=args.temperature)
    frontend = BatchingFrontend(engine)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, (args.prompt_len,))
        reqs.append(frontend.submit(prompt.astype(np.int32), args.max_new))
    outs = [r.result.get(timeout=600) for r in reqs]
    frontend.shutdown()
    print(json.dumps({
        "requests": len(outs),
        "batches_served": frontend.batches_served,
        "tokens_generated": int(sum(len(o) for o in outs)),
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
