"""Perf diagnostics for one dry-run cell: top traffic instructions and top
collectives from the trip-weighted HLO analysis (the 'profile' of the
hypothesis loop — see EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.diagnose --arch yi-34b \
        --shape train_4k --mesh single [--top 25]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse      # noqa: E402
import collections   # noqa: E402

from repro.configs.base import SHAPES  # noqa: E402
from repro.launch.dryrun import lower_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline import hlo_parser  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--dump-hlo", default=None,
                    help="write optimized HLO text to this path")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    shape = SHAPES[args.shape]

    # lower_cell returns the artifact dict; we need the HLO, so re-run the
    # tail of it here via a tiny shim: lower_cell stores no HLO (artifacts
    # stay small), so recompute.
    import repro.launch.dryrun as dr
    out = {}
    orig_build = dr.build_report
    captured = {}

    def capture_report(**kw):
        captured["hlo"] = kw["hlo_text"]
        return orig_build(**kw)

    dr.build_report = capture_report
    try:
        out = dr.lower_cell(args.arch, shape, mesh, args.mesh)
    finally:
        dr.build_report = orig_build
    hlo = captured.get("hlo", "")
    if args.dump_hlo and hlo:
        with open(args.dump_hlo, "w") as f:
            f.write(hlo)

    r = out["roofline"]
    print(f"== {args.arch} x {args.shape} x {args.mesh} ==")
    print(f"compute_s={r['compute_s']:.3f} memory_s={r['memory_s']:.3f} "
          f"collective_s={r['collective_s']:.3f} dominant={r['dominant']}")
    print(f"peak/dev={out['memory']['peak_per_device']/2**30:.2f}GiB "
          f"useful_flops={r['useful_flops_ratio']:.3f}")
    print(f"collectives: {r['collective_counts']}")
    bk = r["collective_breakdown"]
    for k, v in sorted(bk.items(), key=lambda kv: -kv[1]):
        if v:
            print(f"  {k:20s} {v/1e9:12.2f} GB/dev")

    # top traffic instructions
    print(f"\n-- top {args.top} traffic instructions (trip-weighted) --")
    rows = hlo_parser.top_traffic(hlo, n=args.top)
    for traffic, mult, comp, op, name, tstr in rows:
        print(f"{traffic/1e9:10.1f} GB x{mult:<6g} {op:22s} {tstr:42s} "
              f"{comp[:28]}/{name[:40]}")

    # top collectives individually
    print(f"\n-- collectives by instruction --")
    coll = []

    def cb(comp, ins, mult, traffic):
        if ins.op in hlo_parser.COLLECTIVE_OPS:
            coll.append((traffic * 0.5 * mult, mult, ins.op, ins.type_str[:48],
                         comp.name[:40]))
    hlo_parser.analyze_module(hlo, on_instr=cb)
    coll.sort(reverse=True)
    for b, mult, op, tstr, comp in coll[:args.top]:
        print(f"{b/1e9:10.2f} GB x{mult:<6g} {op:20s} {tstr:50s} {comp}")

    # loop structure
    print("\n-- while loops --")
    comps = hlo_parser.parse_module(hlo)
    for c in comps.values():
        for ins in c.instrs:
            if ins.op == "while":
                tm = hlo_parser._TRIP_RE.search(ins.attrs() + ins.rest)
                trips = tm.group(1) if tm else "?"
                print(f"  trips={trips:6s} in {c.name[:40]} result="
                      f"{ins.type_str[:60]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
