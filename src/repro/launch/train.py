"""Training launcher: ``python -m repro.launch.train --arch qwen2-0.5b ...``

Wires together everything the framework provides: config registry (--arch
selects any of the 10 assigned architectures, reduced or full), the
DPT-autotuned data pipeline, the jit'd train step, checkpoint/restart and
the straggler/retune hooks.  On a real fleet each host runs this entry
point under the cluster launcher (GKE/xmanager); jax.distributed handles
cross-host init — on this container it runs single-process.
"""
from __future__ import annotations

import argparse
import json
import os


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--no-autotune", action="store_true")
    ap.add_argument("--dpt-cache", default=None)
    ap.add_argument("--num-items", type=int, default=2048)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", default="none",
                    choices=["none", "dots", "nothing", "full"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, reduced
    from repro.data import DataLoader, LoaderParams, token_dataset
    from repro.models import build_model
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import TrainStepConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)

    if cfg.family in ("vlm", "encdec"):
        # modality stubs: wrap the token dataset with stub frontends
        from repro.data.dataset import Dataset, ArrayStorage
        import numpy as np
        rng = np.random.default_rng(args.seed)
        items = [rng.integers(0, cfg.vocab_size,
                              (args.seq_len + 1,)).astype(np.int32)
                 for _ in range(args.num_items)]

        def transform(arr):
            out = {"tokens": arr[:-1], "targets": arr[1:],
                   "loss_mask": np.ones(args.seq_len, np.float32)}
            if cfg.num_patches:
                out["patch_embeds"] = rng.normal(
                    0, 1, (cfg.num_patches, cfg.patch_embed_dim)
                ).astype(np.float32)
            if cfg.encoder_layers:
                out["frames"] = rng.normal(
                    0, 1, (cfg.max_source_positions, cfg.d_model)
                ).astype(np.float32)
            return out

        ds = Dataset(ArrayStorage(items), transform=transform)
    else:
        ds = token_dataset(args.num_items, args.seq_len, cfg.vocab_size,
                           seed=args.seed)

    loader = DataLoader(ds, args.global_batch,
                        params=LoaderParams(num_workers=2),
                        seed=args.seed,
                        host_index=jax.process_index(),
                        host_count=jax.process_count())

    tc = TrainerConfig(
        total_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        autotune=not args.no_autotune,
        dpt_cache_path=args.dpt_cache,
        seed=args.seed,
        step_config=TrainStepConfig(
            remat_policy=args.remat,
            microbatches=args.microbatches,
            compress_grads=args.compress_grads,
            optimizer=AdamWConfig(peak_lr=args.lr,
                                  total_steps=args.steps,
                                  warmup_steps=max(2, args.steps // 20))),
    )
    trainer = Trainer(model, loader, tc)
    result = trainer.run()
    print(json.dumps(result, default=float))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
