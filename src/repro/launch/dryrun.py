"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init) — hence the first two lines.  Do NOT import this module from
tests; run it as a script:

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, resumable

Each cell writes artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, collective bytes and roofline terms.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig,  # noqa: E402
                                applicable_shapes, get_config, list_configs)
from repro.distributed.sharding_rules import (ShardingCtx, rules_for,  # noqa: E402
                                              use_rules)
from repro.launch.mesh import make_production_mesh, mesh_chips  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models.module import logical_axes  # noqa: E402
from repro.roofline.analysis import build_report  # noqa: E402
from repro.train.optimizer import abstract_adamw  # noqa: E402
from repro.train.train_step import (TrainState, TrainStepConfig,  # noqa: E402
                                    make_train_step)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# per-arch training knobs sized so every cell fits 16 GB/chip (see DESIGN.md)
# ---------------------------------------------------------------------------
def train_step_config(cfg: ModelConfig) -> TrainStepConfig:
    # microbatch floor of 8 (global 256 -> 32/microbatch): per-layer
    # activation checkpoints and large-vocab logit transients both scale
    # with the microbatch size; the v0 baseline at mb=1 blew the 16 GB HBM
    # budget on every mid-size arch (see EXPERIMENTS.md §Perf iteration 1).
    # dp_manual=True is §Perf iteration 2: explicit-DP shard_map step (bf16
    # FSDP gathers, once-per-step grad psum, EP MoE, sharded fused xent).
    n = cfg.param_count()
    if n > 50e9:
        mb, remat = 16, "nothing"
    elif n > 20e9:
        mb, remat = 8, "nothing"
    else:
        mb, remat = 8, "dots"
    return TrainStepConfig(remat_policy=remat, microbatches=mb,
                           dp_manual=True)


def use_seq_parallel(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    # iteration 2: OFF — under the pjit path Megatron-style seq-parallelism
    # made GSPMD re-shard (all-gather) the f32 WEIGHTS per layer instead of
    # the activations (EXPERIMENTS.md §Perf yi-34b iteration); the manual-DP
    # step keeps activations replicated over 'model' and TP handles the
    # heavy matmuls.
    return False


def serve_params_dtype(t):
    return jax.ShapeDtypeStruct(t.shape, jnp.bfloat16) \
        if t.dtype == jnp.float32 else t


def choose_kv_dtype(model, cfg: ModelConfig, shape: ShapeConfig, chips: int):
    """fp8 KV-cache quantization when the bf16 cache would exceed ~7 GB per
    device (MHA archs at 32k x 128: phi-3-vision, whisper, mistral, yi)."""
    from repro.utils.tree import tree_bytes
    cache = model.init_cache(shape.global_batch, shape.seq_len, abstract=True)
    per_dev = tree_bytes(cache) / chips
    if per_dev > 7e9:
        return jnp.float8_e4m3fn
    return jnp.bfloat16


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------
def params_shardings(model, ctx: ShardingCtx):
    axes = model.logical_axes()
    abstract = model.abstract_params()
    return jax.tree_util.tree_map(
        lambda ax, arr: ctx.named_sharding(ax, arr.shape), axes, abstract,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def batch_shardings(specs: Dict, ctx: ShardingCtx):
    def shard_for(name, arr):
        if arr.ndim == 1:
            axes = ("batch",)
        elif arr.ndim == 2:
            axes = ("batch", None)
        else:
            axes = ("batch",) + (None,) * (arr.ndim - 1)
        return ctx.named_sharding(axes, arr.shape)
    return {k: shard_for(k, v) for k, v in specs.items()}


CACHE_AXES = {
    "k": ("layers", "batch", "kv_seq", None, None),
    "v": ("layers", "batch", "kv_seq", None, None),
    "cross_k": ("layers", "batch", "kv_seq", None, None),
    "cross_v": ("layers", "batch", "kv_seq", None, None),
    "ssm_conv": ("layers", "batch", None, "ssm_inner"),
    "ssm_state": ("layers", "batch", "ssm_heads", None, None),
}


def cache_shardings(cache_abstract, ctx: ShardingCtx):
    return {k: ctx.named_sharding(CACHE_AXES[k], v.shape)
            for k, v in cache_abstract.items()}


def opt_state_shardings(model, ctx: ShardingCtx):
    p = params_shardings(model, ctx)
    scalar = ctx.named_sharding((), ())
    from repro.train.optimizer import AdamWState
    return AdamWState(step=scalar, mu=p, nu=p)


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------
def _serve_wrap(model, cfg, ctx, fn, *, global_batch: int = 0,
                out_is_cache_second=True):
    """Wrap a serve fn (prefill/decode) in shard_map over the batch axes so
    the manual paths (per-layer bf16 FSDP gathers for >50B archs, local EP
    MoE dispatch) activate — the pjit MoE dispatch was 121-126 GiB/dev on
    the 32k prefill cells (EXPERIMENTS.md §Perf iteration 6)."""
    import jax.tree_util as jtu
    from jax.sharding import PartitionSpec as P

    from repro.distributed import dp_shard
    mesh = ctx.mesh
    manual = dp_shard.manual_axes(mesh)
    if not manual or not dp_shard.validate_manual_divisibility(
            ctx, model.logical_axes(), model.abstract_params(), manual):
        return None
    if global_batch and global_batch % dp_shard.manual_size(mesh):
        return None   # long_500k: batch 1 can't shard over the DP axes
    axes_tree = model.logical_axes()
    p_specs = dp_shard.param_manual_specs(ctx, axes_tree,
                                          model.abstract_params(), manual)
    bspec = P(manual if len(manual) > 1 else manual[0])

    def cache_mspec(axes):
        ents = [tuple(a for a in (TRAIN_MANUAL_BATCH if n == "batch" else ())
                      if a in manual) or None for n in axes]
        ents = [e[0] if isinstance(e, tuple) and len(e) == 1 else e
                for e in ents]
        while ents and ents[-1] is None:
            ents.pop()
        return P(*ents)

    def wrapped(params, batch, cache):
        def body(params, batch, cache):
            with ctx.manual_region(set(manual)):
                params_g = dp_shard.gather_params(params, axes_tree)
                return fn(params_g, batch, cache)
        c_specs = {k: cache_mspec(CACHE_AXES[k]) for k in cache}
        b_specs = jtu.tree_map(lambda _: bspec, batch)
        out_specs = (bspec, c_specs)
        return jax.shard_map(body, mesh=mesh,
                             in_specs=(p_specs, b_specs, c_specs),
                             out_specs=out_specs,
                             axis_names=set(manual), check_vma=False)(
            params, batch, cache)

    return wrapped


TRAIN_MANUAL_BATCH = ("pod", "data")


def lower_cell(arch: str, shape: ShapeConfig, mesh, mesh_name: str,
               *, do_compile: bool = True) -> dict:
    cfg = get_config(arch)
    model = build_model(cfg)
    rules = rules_for(shape.kind,
                      seq_parallel=use_seq_parallel(cfg, shape),
                      big_params=cfg.param_count() > 20e9)
    t0 = time.perf_counter()

    with use_rules(mesh, rules) as ctx:
        if shape.kind == "train":
            scfg = train_step_config(cfg)
            step = make_train_step(model, scfg)
            p_sh = params_shardings(model, ctx)
            state_sh = TrainState(p_sh, opt_state_shardings(model, ctx), None)
            state_abs = TrainState(model.abstract_params(),
                                   abstract_adamw(model.abstract_params()),
                                   None)
            in_specs = model.input_specs(shape)
            b_sh = batch_shardings(in_specs, ctx)
            jf = jax.jit(step, in_shardings=(state_sh, b_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
            lowered = jf.lower(state_abs, in_specs)
        elif shape.kind == "prefill":
            params_abs = jax.tree_util.tree_map(serve_params_dtype,
                                                model.abstract_params())
            p_sh = params_shardings(model, ctx)
            kv_dtype = choose_kv_dtype(model, cfg, shape, mesh_chips(mesh))
            cache_abs = model.init_cache(shape.global_batch, shape.seq_len,
                                         abstract=True, kv_dtype=kv_dtype)
            c_sh = cache_shardings(cache_abs, ctx)
            in_specs = model.input_specs(shape)
            b_sh = batch_shardings(in_specs, ctx)
            logits_sh = ctx.named_sharding(
                ("batch", None, "vocab_act"),
                (shape.global_batch, 1, cfg.vocab_size))

            def prefill(params, batch, cache):
                return model.prefill(params, batch, cache)

            wrapped = _serve_wrap(model, cfg, ctx, model.prefill,
                                  global_batch=shape.global_batch)
            if wrapped is not None:
                prefill = wrapped
            jf = jax.jit(prefill, in_shardings=(p_sh, b_sh, c_sh),
                         out_shardings=(logits_sh, c_sh),
                         donate_argnums=(2,))
            lowered = jf.lower(params_abs, in_specs, cache_abs)
        else:  # decode
            params_abs = jax.tree_util.tree_map(serve_params_dtype,
                                                model.abstract_params())
            p_sh = params_shardings(model, ctx)
            kv_dtype = choose_kv_dtype(model, cfg, shape, mesh_chips(mesh))
            cache_abs = model.init_cache(shape.global_batch, shape.seq_len,
                                         abstract=True, kv_dtype=kv_dtype)
            c_sh = cache_shardings(cache_abs, ctx)
            in_specs = model.input_specs(shape)
            b_sh = batch_shardings(in_specs, ctx)
            logits_sh = ctx.named_sharding(
                ("batch", None, "vocab_act"),
                (shape.global_batch, 1, cfg.vocab_size))

            # decode stays on the pjit path: its MoE touches only B tokens
            # (no dispatch blow-up) and the manual wrapper's threaded cache
            # picks up replicated f32 loop-state twins on the CPU backend
            # (granite decode 3.7 -> 21 GiB; see EXPERIMENTS.md §Perf it. 6).
            def decode(params, cache, tokens, positions):
                return model.decode_step(params, cache, tokens, positions)

            jf = jax.jit(decode,
                         in_shardings=(p_sh, c_sh, b_sh["tokens"],
                                       b_sh["positions"]),
                         out_shardings=(logits_sh, c_sh),
                         donate_argnums=(1,))
            lowered = jf.lower(params_abs, cache_abs, in_specs["tokens"],
                               in_specs["positions"])

    t_lower = time.perf_counter() - t0
    out = {
        "arch": arch, "shape": shape.name, "mesh": mesh_name,
        "chips": mesh_chips(mesh),
        "lower_s": round(t_lower, 2),
        "dropped_shardings": [list(map(str, d)) for d in ctx.dropped[:20]],
        "ok": True,
    }
    if not do_compile:
        return out

    t1 = time.perf_counter()
    compiled = lowered.compile()
    out["compile_s"] = round(time.perf_counter() - t1, 2)

    mem = compiled.memory_analysis()
    out["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_per_device": int(mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes
                               + mem.output_size_in_bytes
                               - mem.alias_size_in_bytes),
    }
    cost = compiled.cost_analysis()
    out["cost"] = {"flops": float(cost.get("flops", 0.0)),
                   "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    hlo = compiled.as_text()
    report = build_report(arch=arch, shape=shape, mesh_name=mesh_name,
                          chips=mesh_chips(mesh), cost=cost, mem=mem,
                          hlo_text=hlo, cfg=cfg)
    out["roofline"] = report.to_dict()
    out["fits_hbm_16g"] = out["memory"]["peak_per_device"] < 16e9
    return out


def cell_path(arch: str, shape_name: str, mesh_name: str) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    return os.path.join(ARTIFACTS, f"{arch}__{shape_name}__{mesh_name}.json")


def run_cell(arch: str, shape_name: str, mesh_name: str,
             *, force: bool = False) -> dict:
    path = cell_path(arch, shape_name, mesh_name)
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    shape = SHAPES[shape_name]
    try:
        out = lower_cell(arch, shape, mesh, mesh_name)
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        out = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def all_cells():
    for arch in list_configs():
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            for mesh_name in ("single", "multi"):
                yield arch, shape.name, mesh_name


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for c in all_cells():
            print("/".join(c))
        return 0

    if args.all:
        failures = 0
        for arch, shape_name, mesh_name in all_cells():
            out = run_cell(arch, shape_name, mesh_name, force=args.force)
            status = "OK " if out.get("ok") else "FAIL"
            extra = ""
            if out.get("ok") and "memory" in out:
                extra = (f" peak/dev={out['memory']['peak_per_device']/2**30:.2f}GiB"
                         f" dominant={out['roofline']['dominant']}")
            print(f"[{status}] {arch} x {shape_name} x {mesh_name}{extra}",
                  flush=True)
            failures += 0 if out.get("ok") else 1
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    out = run_cell(args.arch, args.shape, args.mesh, force=args.force)
    print(json.dumps({k: v for k, v in out.items() if k != "traceback"},
                     indent=1))
    return 0 if out.get("ok") else 1


if __name__ == "__main__":
    raise SystemExit(main())
