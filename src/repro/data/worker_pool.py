"""Worker pools: the parallel fetch+transform lanes that DPT's nWorker tunes.

``ThreadWorkerPool`` is the default (DESIGN.md: numpy/IO transforms release
the GIL, and TPU hosts run one Python process per host — threads are the
idiomatic JAX-host analogue of PyTorch's forked dataloader workers).
``ProcessWorkerPool`` is the fallback for GIL-heavy transforms.

Backpressure implements PyTorch ``prefetch_factor`` semantics: at most
``num_workers * prefetch_factor`` finished batches may be queued; workers
block (stop consuming memory) when the consumer lags.

Both pools support ``request_drain()``: stop pulling new index-batches but
deliver everything already pulled, then end the consumer's iteration.
Because indices are only pulled under a lock and every pulled index-batch
is eventually enqueued, a drain loses nothing and duplicates nothing —
this is what lets a live DataLoader hot-swap (nWorker, nPrefetch) at a
batch boundary (see data/loader.py LoaderStream).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.monitor import MemoryMonitor, MemoryOverflow

_SENTINEL = object()


def batch_nbytes(batch) -> int:
    if isinstance(batch, dict):
        return int(sum(np.asarray(v).nbytes for v in batch.values()))
    return int(np.asarray(batch).nbytes)


class _DrainableIter:
    """Iterator wrapper that can be told to stop yielding at a boundary.

    ``drain()`` makes the next ``__next__`` raise StopIteration; items
    already handed out are unaffected.  Thread-safe by virtue of callers
    serializing ``__next__`` (the pools pull under a lock / from a single
    thread) and ``drain`` being a single Event set.
    """

    def __init__(self, it: Iterator):
        self._it = iter(it)
        self._stop = threading.Event()

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        return next(self._it)

    def drain(self) -> None:
        self._stop.set()


class ThreadWorkerPool:
    """Pulls index-batches from ``index_iter``, emits collated batches."""

    def __init__(self, dataset, index_iter: Iterator[np.ndarray], *,
                 num_workers: int, prefetch_factor: int = 2,
                 monitor: Optional[MemoryMonitor] = None):
        self.dataset = dataset
        self.num_workers = max(0, num_workers)
        self.prefetch_factor = max(1, prefetch_factor)
        self.monitor = monitor or MemoryMonitor()
        self._index_iter = _DrainableIter(index_iter)
        self._iter_lock = threading.Lock()
        self._error: Optional[BaseException] = None
        self._stop = threading.Event()

        if self.num_workers == 0:
            self._queue = None
            self._threads = []
            return
        depth = self.num_workers * self.prefetch_factor
        self._queue: queue.Queue = queue.Queue(maxsize=depth)
        self._live = self.num_workers
        self._live_lock = threading.Lock()
        self._threads = [
            threading.Thread(target=self._work, name=f"loader-worker-{i}",
                             daemon=True)
            for i in range(self.num_workers)]
        for t in self._threads:
            t.start()

    # ---- worker body -------------------------------------------------------
    def _next_indices(self):
        with self._iter_lock:
            return next(self._index_iter)

    def _work(self):
        try:
            while not self._stop.is_set():
                try:
                    idx = self._next_indices()
                except StopIteration:
                    break
                batch = self.dataset.get_batch(idx)
                nbytes = batch_nbytes(batch)
                self.monitor.reserve(nbytes)
                self._queue.put((batch, nbytes))
        except BaseException as e:  # noqa: BLE001 - surfaced to consumer
            self._error = e
        finally:
            with self._live_lock:
                self._live -= 1
                if self._live == 0:
                    self._queue.put(_SENTINEL)

    # ---- consumer side -----------------------------------------------------
    def request_drain(self) -> None:
        """Stop pulling new index-batches; already-pulled batches still
        deliver, then iteration ends (the hot-swap batch boundary)."""
        self._index_iter.drain()

    def __iter__(self):
        if self.num_workers == 0:
            for idx in self._index_iter:   # _DrainableIter ends on drain
                yield self.dataset.get_batch(idx)
            return
        while True:
            item = self._queue.get()
            if item is _SENTINEL:
                if self._error is not None:
                    raise self._error
                return
            batch, nbytes = item
            self.monitor.release(nbytes)
            if self._error is not None:
                self.shutdown()
                raise self._error
            yield batch

    def shutdown(self):
        self._stop.set()
        if self._queue is not None:
            try:
                while True:
                    item = self._queue.get_nowait()
                    if item is not _SENTINEL:
                        self.monitor.release(item[1])
            except queue.Empty:
                pass


class ProcessWorkerPool:
    """Process-based fallback (GIL-heavy transforms).  Uses a fork pool and
    chunked imap; heavier per-batch overhead, same interface."""

    def __init__(self, dataset, index_iter, *, num_workers: int,
                 prefetch_factor: int = 2,
                 monitor: Optional[MemoryMonitor] = None):
        import multiprocessing as mp
        self.dataset = dataset
        self.monitor = monitor or MemoryMonitor()
        self._indices = _DrainableIter(index_iter)
        self.num_workers = max(1, num_workers)
        self.prefetch_factor = max(1, prefetch_factor)
        self._pool = mp.get_context("fork").Pool(self.num_workers)

    def request_drain(self) -> None:
        self._indices.drain()

    def __iter__(self):
        try:
            for batch in self._pool.imap(
                    self.dataset.get_batch, self._indices,
                    chunksize=1):
                self.monitor.reserve(batch_nbytes(batch))
                self.monitor.release(batch_nbytes(batch))
                yield batch
        finally:
            self.shutdown()

    def shutdown(self):
        self._pool.terminate()
