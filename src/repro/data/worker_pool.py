"""Worker pools: the parallel fetch+transform lanes that DPT's nWorker tunes.

``ThreadWorkerPool`` is the default (DESIGN.md: numpy/IO transforms release
the GIL, and TPU hosts run one Python process per host — threads are the
idiomatic JAX-host analogue of PyTorch's forked dataloader workers).
``ProcessWorkerPool`` is the fallback for GIL-heavy transforms.

Backpressure implements PyTorch ``prefetch_factor`` semantics: at most
``num_workers * prefetch_factor`` finished batches may be queued; workers
block (stop consuming memory) when the consumer lags.  ``ProcessWorkerPool``
bounds its in-flight task window to the same depth (its consumer-driven
pump submits at most that many sequences ahead), so process mode has real
backpressure too.

Fault tolerance (DESIGN.md §10): with a ``fault_policy`` the task bodies
run reads through retry/quarantine/batch-repair machinery (data/faults.py)
so transient storage faults never escape a worker; a policy-skipped batch
consumes its sequence slot (``on_skip`` tells the stream) instead of
killing the pool, and a SIGKILL'd process-pool child costs one resubmit
instead of the stream.  Without a policy, any worker exception remains
pool-fatal exactly as before.

Delivery is **order-preserving** by default (``ordered=True``): every
index-batch gets a sequence number when it is pulled from the sampler, and
a small reordering buffer on the consumer side yields batches in exactly
sampler order at any worker count — what lets hot-swap accounting assert
exact batch sequences.  ``ordered=False`` restores completion-order
delivery (slightly lower head-of-line latency); it is thread-pool only —
``ProcessWorkerPool`` rejects it (its delivery is inherently ordered).

Dual-lane slow-sample isolation (DESIGN.md §9): ordered delivery has a
straggler pathology — the sequence window parks every fast batch behind
one slow decode.  With ``slow_lane_workers > 0`` and a ``cost_tracker``
(data/costs.py), index-batches are *classified at pull time*: predicted-
slow batches go to a dedicated slow lane whose sequence window runs
``slow_lane_lookahead`` batches AHEAD of the fast lane's, so stragglers
start early and finish by the time the consumer's cursor reaches them.
Lanes share the sequence space and merge at the existing reorder buffer,
so delivered order and the byte-identical multiset guarantee are
unchanged; the lanes differ only in *when* work starts.  Dispatch is
work-conserving: an idle lane steals the other lane's head rather than
sleeping next to pending work.

Zero-copy fast path (DESIGN.md §3): given a ``SlabArena``, workers acquire
a recycled slot, collate straight into its slabs, and pass the *slot token*
through the queue — ``nbytes`` comes from the slot (computed once at spec
time), and the consumer's advance recycles the slot.  Hot-swap drain
delivers every in-flight slot before the pool retires, so nothing leaks.

Both pools support ``request_drain()``: stop pulling new index-batches but
deliver everything already pulled, then end the consumer's iteration.
Because indices are only pulled under a lock and every pulled index-batch
is eventually enqueued (parked lane entries are pulled: they drain too),
a drain loses nothing and duplicates nothing — this is what lets a live
DataLoader hot-swap (nWorker, nPrefetch) at a batch boundary (see
data/loader.py LoaderStream).
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Iterator, Optional

import numpy as np

from repro.core.monitor import MemoryMonitor, MemoryOverflow
from repro.data.arena import ArenaBatch, SlabArena, maybe_release

_SENTINEL = object()
_SKIPPED = object()      # a fault policy dropped the whole batch: the
#                          sequence slot is consumed but nothing is yielded
_POOL_STOPPED = object()


def _mp_get_batch(dataset, fast, idx):
    """Module-level task fn so the fork pool pickles only (dataset, fast)."""
    return dataset.get_batch(idx, fast=fast)


def _mp_get_batch_timed(dataset, fast, idx):
    """Timed variant: ships (batch, wall seconds) back so the parent can
    feed its cost tracker — children stay stateless across tasks."""
    t0 = time.perf_counter()
    batch = dataset.get_batch(idx, fast=fast)
    return batch, time.perf_counter() - t0


def _mp_resilient_batch(dataset, fast, policy, idx):
    """Fault-tolerant task body (DESIGN.md §10): the child runs the read
    through a pickled ``FaultPolicy`` snapshot and ships back (batch or
    None, wall seconds, tally) — the parent merges quarantined ids and
    fault counts into its live log/stats."""
    report: dict = {}
    t0 = time.perf_counter()
    batch = policy.get_batch(dataset, idx, fast=fast, report=report)
    return batch, time.perf_counter() - t0, report


def _record_cost(cost_tracker, fault_policy, idx, dt) -> None:
    """Fold a batch's wall time into the cost tracker, excluding ids the
    policy just quarantined — their forgotten EWMA slots must not be
    repopulated by the very batch that withdrew them."""
    if fault_policy is not None and len(fault_policy.quarantine):
        idx = np.asarray(idx).reshape(-1)
        idx = idx[~np.isin(idx, fault_policy.quarantine.ids())]
        if idx.size == 0:
            return
    cost_tracker.record(idx, dt)


def batch_nbytes(batch) -> int:
    if isinstance(batch, ArenaBatch):
        return batch.nbytes          # computed once at slot reservation
    if isinstance(batch, dict):
        return int(sum(np.asarray(v).nbytes for v in batch.values()))
    return int(np.asarray(batch).nbytes)


class _DrainableIter:
    """Iterator wrapper that can be told to stop yielding at a boundary.

    ``drain()`` makes the next ``__next__`` raise StopIteration; items
    already handed out are unaffected.  Thread-safe by virtue of callers
    serializing ``__next__`` (the pools pull under a lock / from a single
    thread) and ``drain`` being a single Event set.
    """

    def __init__(self, it: Iterator):
        self._it = iter(it)
        self._stop = threading.Event()

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        return next(self._it)

    def drain(self) -> None:
        self._stop.set()

    @property
    def drained(self) -> bool:
        return self._stop.is_set()


class ThreadWorkerPool:
    """Pulls index-batches from ``index_iter``, emits collated batches."""

    def __init__(self, dataset, index_iter: Iterator[np.ndarray], *,
                 num_workers: int, prefetch_factor: int = 2,
                 monitor: Optional[MemoryMonitor] = None,
                 ordered: bool = True, fast: bool = True,
                 arena: Optional[SlabArena] = None,
                 cost_tracker=None, slow_lane_workers: int = 0,
                 slow_lane_lookahead: int = 8,
                 fault_policy=None, on_skip=None):
        self.dataset = dataset
        self.num_workers = max(0, num_workers)
        self.prefetch_factor = max(1, prefetch_factor)
        self.monitor = monitor or MemoryMonitor()
        self.ordered = ordered
        self.fast = fast
        # data/faults.py FaultPolicy: retries + quarantine + batch repair
        # inside the task body, so transient faults never kill the pool.
        # ``on_skip`` fires (on the consumer thread) for each sequence
        # slot the policy dropped entirely — streams keep their position
        # accounting exact.
        self.fault_policy = fault_policy
        self.on_skip = on_skip
        self.arena = arena if (fast and getattr(
            dataset, "supports_fast_path", False)) else None
        self.cost_tracker = cost_tracker
        # The slow lane only makes sense where the straggler pathology
        # exists (ordered + threaded) and a predictor is available.
        self.slow_lane_workers = max(0, slow_lane_workers) if (
            ordered and cost_tracker is not None
            and self.num_workers > 0) else 0
        self.slow_lane_lookahead = max(0, slow_lane_lookahead)
        self._index_iter = _DrainableIter(index_iter)
        # One condition guards all dispatch state (_seq/_delivered/_ready/
        # _exhausted) and is notified on EVERY transition — delivery, lane
        # hand-off, drain, stop, exhaustion — so waits are event-driven;
        # the wait timeout below is a backstop, not the reaction latency.
        self._cond = threading.Condition()
        self._seq = 0
        self._delivered = 0
        self._ready = {False: deque(), True: deque()}   # lane -> (seq, idx)
        self._exhausted = False
        self._error: Optional[BaseException] = None
        self._stop = threading.Event()

        if self.num_workers == 0:
            self._queue = None
            self._threads = []
            return
        depth = self.num_workers * self.prefetch_factor
        # Ordered mode: the consumer parks out-of-order arrivals in a
        # reordering buffer, which frees queue slots — without a cap on the
        # *sequence window*, workers behind one straggler could pull and
        # collate the whole epoch (unbounded memory).  A worker may not pull
        # sequence S until S - delivered < window.  The slow lane's window
        # is `slow_lane_lookahead` wider: that headroom is the early start.
        total_workers = self.num_workers + self.slow_lane_workers
        self._window = depth + total_workers
        self._queue: queue.Queue = queue.Queue(maxsize=depth)
        self._live = total_workers
        self._live_lock = threading.Lock()
        self._threads = [
            threading.Thread(target=self._work, args=(False,),
                             name=f"loader-worker-{i}", daemon=True)
            for i in range(self.num_workers)]
        self._threads += [
            threading.Thread(target=self._work, args=(True,),
                             name=f"loader-slow-{i}", daemon=True)
            for i in range(self.slow_lane_workers)]
        for t in self._threads:
            t.start()

    # ---- batch production --------------------------------------------------
    def _mark_delivered(self):
        with self._cond:
            self._delivered += 1
            self._cond.notify_all()

    def _lane_limit(self, lane_slow: bool) -> float:
        """Sequence-window bound for this lane.  A drain lifts the bound:
        the consumer may have stopped advancing, and everything already
        pulled must still deliver."""
        if not self.ordered or self._index_iter.drained:
            return float("inf")
        return self._window + (self.slow_lane_lookahead if lane_slow else 0)

    def _classify(self, idx) -> bool:
        """Route one pulled index-batch: True = slow lane."""
        if self.slow_lane_workers == 0:
            return False
        if not self.cost_tracker.is_slow(idx):
            return False
        self.cost_tracker.note_slow_batch()
        return True

    def _next_indices(self, lane_slow: bool = False):
        """One (seq, idx) for this lane, honoring the lane's window.

        Under the single condition: serve the lane's own parked queue
        first (lowest seq — parked entries arrive in pull order), else
        pull+classify from the shared stream (handing off batches
        classified for the other lane), else steal the other lane's head
        (work conservation: never sleep next to admissible work).  Raises
        StopIteration when the stream is exhausted/drained and every
        parked entry has been taken.
        """
        with self._cond:
            while True:
                if self._stop.is_set():
                    raise StopIteration
                limit = self._lane_limit(lane_slow)
                own = self._ready[lane_slow]
                if own and own[0][0] - self._delivered < limit:
                    return own.popleft()
                if not self._exhausted \
                        and self._seq - self._delivered < limit:
                    try:
                        idx = next(self._index_iter)
                    except StopIteration:
                        self._exhausted = True
                        self._cond.notify_all()
                        continue
                    seq = self._seq
                    self._seq += 1
                    if self._classify(idx) == lane_slow:
                        return seq, idx
                    self._ready[not lane_slow].append((seq, idx))
                    self._cond.notify_all()
                    continue
                other = self._ready[not lane_slow]
                if other and other[0][0] - self._delivered < limit:
                    return other.popleft()
                if (self._exhausted or self._index_iter.drained) \
                        and not own and not other:
                    raise StopIteration
                self._cond.wait(0.5)

    def _acquire_slot(self):
        """Reserve an arena slot (None: no arena / spec unknown / stopped).

        Workers call this BEFORE pulling a sequence number.  Ordering
        matters for liveness: the ordered consumer pins later-sequence
        batches in its reordering buffer until the head sequence arrives,
        so a worker that pulled a sequence and only then waited for a slot
        could starve behind its own successors.  Acquire-first guarantees
        every pulled-but-undelivered batch already owns its buffer and can
        always complete.  (With the slow lane on, ``LoaderParams.
        arena_capacity`` widens by the lookahead so early-started slow
        batches can't exhaust the slots the head still needs.)
        """
        if self.arena is None:
            return None
        return self.arena.acquire(stop=self._stop)

    def _get(self, idx, out=None):
        """The read, through the fault policy when one is armed (None =
        every index of the batch is quarantined: skip the slot)."""
        if self.fault_policy is not None:
            return self.fault_policy.get_batch(self.dataset, idx, out=out,
                                               fast=self.fast)
        return self.dataset.get_batch(idx, out=out, fast=self.fast)

    def _collate(self, idx, slot):
        """One collated batch (+ its nbytes), into ``slot`` if given.
        ``(None, 0)`` means the fault policy dropped the whole batch."""
        if slot is not None:
            batch = self._get(idx, out=slot.arrays)
            if batch is None:
                slot.release()
                return None, 0
            if batch is not slot.arrays:    # slab didn't fit (ragged tail)
                slot.release()
                return batch, batch_nbytes(batch)
            return ArenaBatch(slot), slot.nbytes
        batch = self._get(idx)
        if batch is None:
            return None, 0
        if self.arena is not None:
            adopted = self.arena.adopt(batch)   # establishes the spec
            if adopted is not None:
                return ArenaBatch(adopted), adopted.nbytes
        return batch, batch_nbytes(batch)

    # ---- worker body -------------------------------------------------------
    def _halt(self):
        """Stop flag + wake everything that might be parked on it."""
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if self.arena is not None:
            self.arena.wake()

    def _work(self, lane_slow: bool = False):
        try:
            while not self._stop.is_set():
                slot = self._acquire_slot()
                if slot is None and self.arena is not None \
                        and self._stop.is_set():
                    break
                try:
                    seq, idx = self._next_indices(lane_slow)
                except StopIteration:
                    if slot is not None:
                        slot.release()
                    break
                try:
                    t0 = time.perf_counter()
                    batch, nbytes = self._collate(idx, slot)
                    dt = time.perf_counter() - t0
                except BaseException:
                    if slot is not None:    # not yet wrapped: recycle it
                        slot.release()
                    raise
                if batch is None:           # policy dropped the batch: the
                    #                         slot still consumes its seq
                    self._queue.put((seq, _SKIPPED, 0))
                    continue
                if self.cost_tracker is not None:
                    _record_cost(self.cost_tracker, self.fault_policy,
                                 idx, dt)
                try:
                    self.monitor.reserve(nbytes)
                    self._queue.put((seq, batch, nbytes))
                except BaseException:
                    maybe_release(batch, owned_only=False)
                    raise
        except BaseException as e:  # noqa: BLE001 - surfaced to consumer
            self._error = e
            # A died worker leaves a hole in the sequence: the ordered
            # consumer would park every later batch forever while healthy
            # workers keep producing.  An error is pool-fatal — stop the
            # siblings so the sentinel (and the raise) arrives promptly.
            self._halt()
        finally:
            with self._live_lock:
                self._live -= 1
                if self._live == 0:
                    self._queue.put(_SENTINEL)

    # ---- consumer side -----------------------------------------------------
    def request_drain(self) -> None:
        """Stop pulling new index-batches; already-pulled batches still
        deliver, then iteration ends (the hot-swap batch boundary)."""
        self._index_iter.drain()
        with self._cond:            # drain lifts windows: wake the waiters
            self._cond.notify_all()

    def _iter_inline(self):
        prev = None
        try:
            for idx in self._index_iter:   # _DrainableIter ends on drain
                slot = self._acquire_slot()
                if slot is None and self.arena is not None \
                        and self._stop.is_set():
                    return
                t0 = time.perf_counter()
                batch, _ = self._collate(idx, slot)
                if batch is None:
                    if self.on_skip is not None:
                        self.on_skip()
                    continue
                if self.cost_tracker is not None:
                    _record_cost(self.cost_tracker, self.fault_policy,
                                 idx, time.perf_counter() - t0)
                maybe_release(prev)        # consumer advanced past it
                prev = batch               # set BEFORE yield: teardown at
                yield batch                # the yield still recycles it
        finally:
            maybe_release(prev)

    def __iter__(self):
        if self.num_workers == 0:
            yield from self._iter_inline()
            return
        reorder: dict = {}
        next_seq = 0
        prev = None
        try:
            while True:
                if self.ordered and next_seq in reorder:
                    batch, nbytes = reorder.pop(next_seq)
                else:
                    item = self._queue.get()
                    if item is _SENTINEL:
                        if self._error is not None:
                            raise self._error
                        # drain any stragglers the buffer still holds
                        for seq in sorted(reorder):
                            batch, nbytes = reorder.pop(seq)
                            self.monitor.release(nbytes)
                            if batch is _SKIPPED:
                                if self.on_skip is not None:
                                    self.on_skip()
                                continue
                            maybe_release(prev)
                            prev = batch
                            yield batch
                        return
                    seq, batch, nbytes = item
                    if self.ordered and seq != next_seq:
                        reorder[seq] = (batch, nbytes)
                        continue
                self.monitor.release(nbytes)
                next_seq += 1
                self._mark_delivered()
                if self._error is not None:
                    maybe_release(batch, owned_only=False)  # in hand, unyielded
                    self.shutdown()
                    raise self._error
                if batch is _SKIPPED:      # every id was quarantined: the
                    #                        slot advances, nothing arrives
                    if self.on_skip is not None:
                        self.on_skip()
                    continue
                maybe_release(prev)        # consumer advanced past it
                prev = batch               # set BEFORE yield: teardown at
                yield batch                # the yield still recycles it
        finally:
            maybe_release(prev)
            for batch, nbytes in reorder.values():   # abandoned mid-buffer
                self.monitor.release(nbytes)
                maybe_release(batch, owned_only=False)
            reorder.clear()

    def shutdown(self):
        """Stop workers and recycle everything in flight.

        Must leave NO arena slot behind: workers parked in ``queue.put``
        hold reserved batches, so the queue is drained repeatedly (each get
        admits a blocked put, whose worker then sees the stop flag and
        exits) until every worker thread is gone and the queue is empty.
        """
        self._index_iter.drain()
        self._halt()
        if self._queue is None:
            return
        while (any(t.is_alive() for t in self._threads)
               or not self._queue.empty()):
            try:
                item = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            if item is not _SENTINEL:
                self.monitor.release(item[2])
                maybe_release(item[1], owned_only=False)


def _pw_worker_main(conn, dataset, fast, timed):
    """Child loop: recv ``(seq, idx, policy)`` tasks on a private duplex
    pipe, ship ``(seq, err, payload)`` back.  ``None`` is the shutdown
    sentinel.  Exceptions are shipped, not raised — the parent re-raises
    them in sequence order."""
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break
        if msg is None:
            break
        seq, idx, pol = msg
        try:
            if pol is not None:
                out = _mp_resilient_batch(dataset, fast, pol, idx)
            elif timed:
                out = _mp_get_batch_timed(dataset, fast, idx)
            else:
                out = _mp_get_batch(dataset, fast, idx)
            err = None
        except BaseException as e:  # noqa: BLE001 — shipped to the parent
            out, err = None, e
        try:
            conn.send((seq, err, out))
        except Exception:
            try:  # the error itself may not pickle; a repr always does
                conn.send((seq, RuntimeError(repr(err)), None))
            except Exception:
                break
    try:
        conn.close()
    except Exception:
        pass


class _PipeWorker:
    """One child process on a private duplex pipe.  No queue or lock is
    shared between workers, so a SIGKILL'd child poisons only its own
    channel — which the parent reads as EOF, not as a wedged lock."""

    __slots__ = ("proc", "conn", "pid", "inflight", "dead")

    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn
        self.pid = proc.pid
        self.inflight = {}      # seq -> idx array, sent but unanswered
        self.dead = False


class ProcessWorkerPool:
    """Process-based fallback (GIL-heavy transforms).  Heavier per-batch
    overhead than the thread pool, same interface.

    One consumer-driven pump serves every mode: tasks are submitted at
    most ``num_workers * prefetch_factor`` sequences ahead of the
    consumer (real ``prefetch_factor`` backpressure) and joined strictly
    in sequence, so delivery is ALWAYS ordered — ``ordered=False`` is
    rejected loudly; completion-order delivery needs the thread pool.
    Arena slabs cannot cross the process boundary; batches arrive as
    fresh (pickled) dicts, but workers still use the batched read +
    vectorized transform inside the child.

    Transport is per-worker ``Process`` + private duplex ``Pipe`` rather
    than ``multiprocessing.Pool`` — that choice IS the crash containment
    (DESIGN.md §10).  A shared-queue pool cannot survive SIGKILL: idle
    workers block in ``SimpleQueue.get`` *while holding* the queue's read
    lock, so killing one wedges every other worker (and the pool's own
    ``terminate``) on a lock no process will ever release.  With
    point-to-point pipes a corpse only breaks its own channel; the parent
    sees EOF, drains any results the worker managed to ship, respawns a
    replacement, and resubmits exactly the dead worker's in-flight
    sequences — up to ``resubmit_budget`` per task.  A SIGKILL mid-batch
    costs one resubmit, not the stream.

    Dual-lane variant (DESIGN.md §9): with ``slow_lane_workers > 0`` and a
    ``cost_tracker``, predicted-slow batches are submitted as soon as they
    enter the extended (``+ slow_lane_lookahead``) window, fast batches
    only inside the base window.  Same early-start effect as the thread
    pool's slow lane; the lane *width* is shared pool capacity here
    (processes are fungible), so the knob buys lookahead rather than
    dedicated children.

    With a ``fault_policy`` (data/faults.py) the task body runs reads
    through a pickled policy snapshot and ships its tally back; the parent
    merges quarantined ids and fault counts into the live log/stats, and
    ``on_skip`` fires for sequence slots the policy dropped entirely.
    """

    def __init__(self, dataset, index_iter, *, num_workers: int,
                 prefetch_factor: int = 2,
                 monitor: Optional[MemoryMonitor] = None,
                 ordered: bool = True, fast: bool = True,
                 arena: Optional[SlabArena] = None,
                 cost_tracker=None, slow_lane_workers: int = 0,
                 slow_lane_lookahead: int = 8,
                 fault_policy=None, on_skip=None,
                 resubmit_budget: int = 2):
        import multiprocessing as mp
        if not ordered:
            raise ValueError(
                "ProcessWorkerPool delivery is always ordered (strict "
                "in-sequence join); ordered=False is unsupported with "
                "use_processes=True — use the thread pool for "
                "completion-order delivery")
        self.dataset = dataset
        self.monitor = monitor or MemoryMonitor()
        self._indices = _DrainableIter(index_iter)
        self.num_workers = max(1, num_workers)
        self.prefetch_factor = max(1, prefetch_factor)
        self.fast = fast
        self.cost_tracker = cost_tracker
        self.slow_lane_workers = max(0, slow_lane_workers) \
            if cost_tracker is not None else 0
        self.slow_lane_lookahead = max(0, slow_lane_lookahead)
        self.fault_policy = fault_policy
        self.on_skip = on_skip
        self.resubmit_budget = max(0, resubmit_budget)
        self.resubmits = 0
        self._stopped = False
        self._ctx = mp.get_context("fork")
        self._pending: dict = {}    # seq -> [idx, resubmits]
        self._results: dict = {}    # seq -> (err, payload)
        self._workers = [self._spawn_worker()
                         for _ in range(self.num_workers)]
        self._worker_pids = {w.pid for w in self._workers}
        self._dead_pids: set = set()

    def request_drain(self) -> None:
        self._indices.drain()

    # ---- crash containment -------------------------------------------------
    def _spawn_worker(self) -> _PipeWorker:
        parent, child = self._ctx.Pipe()
        proc = self._ctx.Process(
            target=_pw_worker_main,
            args=(child, self.dataset, self.fast,
                  self.cost_tracker is not None),
            daemon=True)
        proc.start()
        child.close()   # the child's fork copy is the only live end now
        return _PipeWorker(proc, parent)

    def _send_task(self, seq: int, idx) -> None:
        """Assign to the least-loaded live worker.  A broken pipe at send
        time is a death like any other: contain it and retry on the
        replacement."""
        while True:
            w = min((w for w in self._workers if not w.dead),
                    key=lambda w: len(w.inflight))
            w.inflight[seq] = idx
            try:
                w.conn.send((seq, idx, self.fault_policy))
                return
            except (OSError, ValueError):
                del w.inflight[seq]     # never sent — not a resubmit
                self._on_death(w)

    def _on_msg(self, w: _PipeWorker, msg) -> None:
        seq, err, out = msg
        w.inflight.pop(seq, None)
        self._results[seq] = (err, out)

    def _on_death(self, w: _PipeWorker) -> None:
        """A worker died (pipe EOF / broken pipe).  Drain any results it
        shipped before dying, respawn a replacement, and resubmit exactly
        its lost in-flight sequences — each up to ``resubmit_budget``."""
        if w.dead:
            return
        w.dead = True
        self._dead_pids.add(w.pid)
        self._worker_pids.discard(w.pid)
        try:
            while w.conn.poll(0):
                self._on_msg(w, w.conn.recv())
        except (EOFError, OSError):
            pass
        try:
            w.conn.close()
        except Exception:
            pass
        w.proc.join(timeout=0.1)
        lost = dict(w.inflight)
        w.inflight.clear()
        if self._stopped:
            return
        replacement = self._spawn_worker()
        self._workers[self._workers.index(w)] = replacement
        self._worker_pids.add(replacement.pid)
        for seq, idx in sorted(lost.items()):
            entry = self._pending.get(seq)
            if entry is None:
                continue
            if entry[1] >= self.resubmit_budget:
                raise RuntimeError(
                    f"process-pool worker died (pid {w.pid}) and an "
                    f"in-flight batch exhausted its resubmit budget "
                    f"({self.resubmit_budget})")
            entry[1] += 1
            self.resubmits += 1
            if self.fault_policy is not None:
                self.fault_policy.stats.note_resubmit()
            self._send_task(seq, idx)

    def _poll(self, timeout: float) -> None:
        """One multiplexed wait over every live worker pipe; EOF on a
        pipe is a worker death handled inline."""
        from multiprocessing import connection as mpc
        live = {w.conn: w for w in self._workers if not w.dead}
        if not live:
            return
        for conn in mpc.wait(list(live), timeout):
            w = live[conn]
            try:
                self._on_msg(w, conn.recv())
            except (EOFError, OSError):
                self._on_death(w)

    def _merge_report(self, report) -> None:
        """Fold a child task's fault tally into the parent's live state."""
        pol = self.fault_policy
        if not report or pol is None:
            return
        newly = []
        for i, reason in report.get("quarantined", ()):
            if pol.quarantine.add(int(i), reason):
                newly.append(int(i))
        if newly and pol.on_quarantine is not None:
            pol.on_quarantine(newly)
        pol.stats.merge_report(report)

    def _join(self, seq: int):
        """Block until the head-of-sequence result arrives, polling the
        worker pipes — a pipe EOF mid-wait is a death and is contained
        inline (respawn + resubmit).  ``_POOL_STOPPED`` = shut down.
        Shipped exceptions re-raise here, in sequence order."""
        while True:
            if seq in self._results:
                err, out = self._results.pop(seq)
                if err is not None:
                    raise err
                return out
            if self._stopped:
                return _POOL_STOPPED
            self._poll(0.05)

    # ---- the pump ----------------------------------------------------------
    def _iter_pump(self):
        pol = self.fault_policy
        timed = self.cost_tracker is not None
        cap = self.num_workers * self.prefetch_factor
        lane = self.slow_lane_workers > 0
        look = cap + (self.slow_lane_lookahead if lane else 0)
        staged: deque = deque()   # (seq, idx) parked outside the base cap
        pending = self._pending   # seq -> [idx, resubmits]
        seq_in = 0
        next_out = 0
        exhausted = False
        it = iter(self._indices)
        while not self._stopped:
            # pull ahead through the window, launching predicted-slow
            # batches immediately (extended window) and parking fast ones
            while not exhausted and seq_in - next_out < look:
                try:
                    idx = next(it)
                except StopIteration:
                    exhausted = True
                    break
                s, seq_in = seq_in, seq_in + 1
                if lane and self.cost_tracker.is_slow(idx):
                    self.cost_tracker.note_slow_batch()
                    pending[s] = [idx, 0]
                    self._send_task(s, idx)
                else:
                    staged.append((s, idx))
            while staged and staged[0][0] - next_out < cap:
                s, idx = staged.popleft()
                pending[s] = [idx, 0]
                self._send_task(s, idx)
            if next_out not in pending:     # everything pulled is delivered
                return
            out = self._join(next_out)
            if out is _POOL_STOPPED:
                return
            idx_done = pending.pop(next_out)[0]
            next_out += 1
            if pol is not None:
                batch, dt, report = out
                self._merge_report(report)
            elif timed:
                batch, dt = out
            else:
                batch, dt = out, None
            if timed and batch is not None:
                _record_cost(self.cost_tracker, pol, idx_done, dt)
            if batch is None:               # policy dropped the batch
                if self.on_skip is not None:
                    self.on_skip()
                continue
            nbytes = batch_nbytes(batch)
            self.monitor.reserve(nbytes)
            self.monitor.release(nbytes)
            yield batch

    def __iter__(self):
        try:
            yield from self._iter_pump()
        finally:
            self.shutdown()

    def shutdown(self):
        # Point-to-point pipes mean no shared queue lock a corpse could
        # hold: send each live worker the sentinel, give the set a short
        # grace to finish the batch in hand, then kill stragglers.  This
        # never blocks on a dead worker (mp.Pool.terminate does — its
        # wind-down acquires the task queue's read lock, which a
        # SIGKILL'd idle worker takes to the grave).
        self._stopped = True
        for w in self._workers:
            if not w.dead:
                try:
                    w.conn.send(None)
                except Exception:
                    pass
        deadline = time.monotonic() + 1.0
        for w in self._workers:
            w.proc.join(timeout=max(0.0, deadline - time.monotonic()))
            if w.proc.is_alive():
                w.proc.kill()
                w.proc.join(timeout=1.0)
            try:
                w.conn.close()
            except Exception:
                pass
