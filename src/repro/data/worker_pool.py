"""Worker pools: the parallel fetch+transform lanes that DPT's nWorker tunes.

``ThreadWorkerPool`` is the default (DESIGN.md: numpy/IO transforms release
the GIL, and TPU hosts run one Python process per host — threads are the
idiomatic JAX-host analogue of PyTorch's forked dataloader workers).
``ProcessWorkerPool`` is the fallback for GIL-heavy transforms.

Backpressure implements PyTorch ``prefetch_factor`` semantics: at most
``num_workers * prefetch_factor`` finished batches may be queued; workers
block (stop consuming memory) when the consumer lags.  ``ProcessWorkerPool``
bounds its in-flight task window to the same depth (a semaphore throttles
the pool's task pump), so process mode has real backpressure too.

Delivery is **order-preserving** by default (``ordered=True``): every
index-batch gets a sequence number when it is pulled from the sampler, and
a small reordering buffer on the consumer side yields batches in exactly
sampler order at any worker count — what lets hot-swap accounting assert
exact batch sequences.  ``ordered=False`` restores completion-order
delivery (slightly lower head-of-line latency).

Zero-copy fast path (DESIGN.md §3): given a ``SlabArena``, workers acquire
a recycled slot, collate straight into its slabs, and pass the *slot token*
through the queue — ``nbytes`` comes from the slot (computed once at spec
time), and the consumer's advance recycles the slot.  Hot-swap drain
delivers every in-flight slot before the pool retires, so nothing leaks.

Both pools support ``request_drain()``: stop pulling new index-batches but
deliver everything already pulled, then end the consumer's iteration.
Because indices are only pulled under a lock and every pulled index-batch
is eventually enqueued, a drain loses nothing and duplicates nothing —
this is what lets a live DataLoader hot-swap (nWorker, nPrefetch) at a
batch boundary (see data/loader.py LoaderStream).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.monitor import MemoryMonitor, MemoryOverflow
from repro.data.arena import ArenaBatch, SlabArena, maybe_release

_SENTINEL = object()


def _mp_get_batch(dataset, fast, idx):
    """Module-level task fn so the fork pool pickles only (dataset, fast)."""
    return dataset.get_batch(idx, fast=fast)


def batch_nbytes(batch) -> int:
    if isinstance(batch, ArenaBatch):
        return batch.nbytes          # computed once at slot reservation
    if isinstance(batch, dict):
        return int(sum(np.asarray(v).nbytes for v in batch.values()))
    return int(np.asarray(batch).nbytes)


class _DrainableIter:
    """Iterator wrapper that can be told to stop yielding at a boundary.

    ``drain()`` makes the next ``__next__`` raise StopIteration; items
    already handed out are unaffected.  Thread-safe by virtue of callers
    serializing ``__next__`` (the pools pull under a lock / from a single
    thread) and ``drain`` being a single Event set.
    """

    def __init__(self, it: Iterator):
        self._it = iter(it)
        self._stop = threading.Event()

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        return next(self._it)

    def drain(self) -> None:
        self._stop.set()

    @property
    def drained(self) -> bool:
        return self._stop.is_set()


class ThreadWorkerPool:
    """Pulls index-batches from ``index_iter``, emits collated batches."""

    def __init__(self, dataset, index_iter: Iterator[np.ndarray], *,
                 num_workers: int, prefetch_factor: int = 2,
                 monitor: Optional[MemoryMonitor] = None,
                 ordered: bool = True, fast: bool = True,
                 arena: Optional[SlabArena] = None):
        self.dataset = dataset
        self.num_workers = max(0, num_workers)
        self.prefetch_factor = max(1, prefetch_factor)
        self.monitor = monitor or MemoryMonitor()
        self.ordered = ordered
        self.fast = fast
        self.arena = arena if (fast and getattr(
            dataset, "supports_fast_path", False)) else None
        self._index_iter = _DrainableIter(index_iter)
        self._iter_lock = threading.Lock()
        self._seq = 0
        self._delivered = 0
        self._window_cond = threading.Condition()
        self._error: Optional[BaseException] = None
        self._stop = threading.Event()

        if self.num_workers == 0:
            self._queue = None
            self._threads = []
            return
        depth = self.num_workers * self.prefetch_factor
        # Ordered mode: the consumer parks out-of-order arrivals in a
        # reordering buffer, which frees queue slots — without a cap on the
        # *sequence window*, workers behind one straggler could pull and
        # collate the whole epoch (unbounded memory).  A worker may not pull
        # sequence S until S - delivered < window.
        self._window = depth + self.num_workers
        self._queue: queue.Queue = queue.Queue(maxsize=depth)
        self._live = self.num_workers
        self._live_lock = threading.Lock()
        self._threads = [
            threading.Thread(target=self._work, name=f"loader-worker-{i}",
                             daemon=True)
            for i in range(self.num_workers)]
        for t in self._threads:
            t.start()

    # ---- batch production --------------------------------------------------
    def _await_window(self):
        """Ordered-mode backpressure: block while the pulled-but-undelivered
        sequence span is at the window bound (wakes on delivery, drain, or
        stop)."""
        with self._window_cond:
            while (self._seq - self._delivered >= self._window
                   and not self._stop.is_set()
                   and not self._index_iter.drained):
                self._window_cond.wait(0.05)

    def _mark_delivered(self):
        with self._window_cond:
            self._delivered += 1
            self._window_cond.notify_all()

    def _next_indices(self):
        if self.ordered:
            self._await_window()
        with self._iter_lock:
            idx = next(self._index_iter)
            seq = self._seq
            self._seq += 1
            return seq, idx

    def _acquire_slot(self):
        """Reserve an arena slot (None: no arena / spec unknown / stopped).

        Workers call this BEFORE pulling a sequence number.  Ordering
        matters for liveness: the ordered consumer pins later-sequence
        batches in its reordering buffer until the head sequence arrives,
        so a worker that pulled a sequence and only then waited for a slot
        could starve behind its own successors.  Acquire-first guarantees
        every pulled-but-undelivered batch already owns its buffer and can
        always complete.
        """
        if self.arena is None:
            return None
        return self.arena.acquire(stop=self._stop)

    def _collate(self, idx, slot):
        """One collated batch (+ its nbytes), into ``slot`` if given."""
        if slot is not None:
            batch = self.dataset.get_batch(idx, out=slot.arrays,
                                           fast=self.fast)
            if batch is not slot.arrays:    # slab didn't fit (ragged tail)
                slot.release()
                return batch, batch_nbytes(batch)
            return ArenaBatch(slot), slot.nbytes
        batch = self.dataset.get_batch(idx, fast=self.fast)
        if self.arena is not None:
            adopted = self.arena.adopt(batch)   # establishes the spec
            if adopted is not None:
                return ArenaBatch(adopted), adopted.nbytes
        return batch, batch_nbytes(batch)

    # ---- worker body -------------------------------------------------------
    def _work(self):
        try:
            while not self._stop.is_set():
                slot = self._acquire_slot()
                if slot is None and self.arena is not None \
                        and self._stop.is_set():
                    break
                try:
                    seq, idx = self._next_indices()
                except StopIteration:
                    if slot is not None:
                        slot.release()
                    break
                try:
                    batch, nbytes = self._collate(idx, slot)
                except BaseException:
                    if slot is not None:    # not yet wrapped: recycle it
                        slot.release()
                    raise
                try:
                    self.monitor.reserve(nbytes)
                    self._queue.put((seq, batch, nbytes))
                except BaseException:
                    maybe_release(batch, owned_only=False)
                    raise
        except BaseException as e:  # noqa: BLE001 - surfaced to consumer
            self._error = e
            # A died worker leaves a hole in the sequence: the ordered
            # consumer would park every later batch forever while healthy
            # workers keep producing.  An error is pool-fatal — stop the
            # siblings so the sentinel (and the raise) arrives promptly.
            self._stop.set()
        finally:
            with self._live_lock:
                self._live -= 1
                if self._live == 0:
                    self._queue.put(_SENTINEL)

    # ---- consumer side -----------------------------------------------------
    def request_drain(self) -> None:
        """Stop pulling new index-batches; already-pulled batches still
        deliver, then iteration ends (the hot-swap batch boundary)."""
        self._index_iter.drain()

    def _iter_inline(self):
        prev = None
        try:
            for idx in self._index_iter:   # _DrainableIter ends on drain
                slot = self._acquire_slot()
                if slot is None and self.arena is not None \
                        and self._stop.is_set():
                    return
                batch, _ = self._collate(idx, slot)
                maybe_release(prev)        # consumer advanced past it
                prev = batch               # set BEFORE yield: teardown at
                yield batch                # the yield still recycles it
        finally:
            maybe_release(prev)

    def __iter__(self):
        if self.num_workers == 0:
            yield from self._iter_inline()
            return
        reorder: dict = {}
        next_seq = 0
        prev = None
        try:
            while True:
                if self.ordered and next_seq in reorder:
                    batch, nbytes = reorder.pop(next_seq)
                else:
                    item = self._queue.get()
                    if item is _SENTINEL:
                        if self._error is not None:
                            raise self._error
                        # drain any stragglers the buffer still holds
                        for seq in sorted(reorder):
                            batch, nbytes = reorder.pop(seq)
                            self.monitor.release(nbytes)
                            maybe_release(prev)
                            prev = batch
                            yield batch
                        return
                    seq, batch, nbytes = item
                    if self.ordered and seq != next_seq:
                        reorder[seq] = (batch, nbytes)
                        continue
                self.monitor.release(nbytes)
                next_seq += 1
                self._mark_delivered()
                if self._error is not None:
                    maybe_release(batch, owned_only=False)  # in hand, unyielded
                    self.shutdown()
                    raise self._error
                maybe_release(prev)        # consumer advanced past it
                prev = batch               # set BEFORE yield: teardown at
                yield batch                # the yield still recycles it
        finally:
            maybe_release(prev)
            for batch, nbytes in reorder.values():   # abandoned mid-buffer
                self.monitor.release(nbytes)
                maybe_release(batch, owned_only=False)
            reorder.clear()

    def shutdown(self):
        """Stop workers and recycle everything in flight.

        Must leave NO arena slot behind: workers parked in ``queue.put``
        hold reserved batches, so the queue is drained repeatedly (each get
        admits a blocked put, whose worker then sees the stop flag and
        exits) until every worker thread is gone and the queue is empty.
        """
        self._stop.set()
        self._index_iter.drain()
        if self._queue is None:
            return
        while (any(t.is_alive() for t in self._threads)
               or not self._queue.empty()):
            try:
                item = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            if item is not _SENTINEL:
                self.monitor.release(item[2])
                maybe_release(item[1], owned_only=False)


class ProcessWorkerPool:
    """Process-based fallback (GIL-heavy transforms).  Uses a fork pool and
    chunked imap; heavier per-batch overhead, same interface.

    In-flight work is bounded to ``num_workers * prefetch_factor``
    index-batches: the task pump blocks on a semaphore that the consumer
    releases per delivered batch — real ``prefetch_factor`` backpressure
    (previously the parameter was accepted and ignored: ``imap`` pumped the
    whole epoch into the task queue).  ``imap`` already preserves submission
    order, so delivery is always ordered.  Arena slabs cannot cross the
    process boundary; batches arrive as fresh (pickled) dicts, but workers
    still use the batched read + vectorized transform inside the child.
    """

    def __init__(self, dataset, index_iter, *, num_workers: int,
                 prefetch_factor: int = 2,
                 monitor: Optional[MemoryMonitor] = None,
                 ordered: bool = True, fast: bool = True,
                 arena: Optional[SlabArena] = None):
        import multiprocessing as mp
        self.dataset = dataset
        self.monitor = monitor or MemoryMonitor()
        self._indices = _DrainableIter(index_iter)
        self.num_workers = max(1, num_workers)
        self.prefetch_factor = max(1, prefetch_factor)
        self.fast = fast
        self._inflight = threading.BoundedSemaphore(
            self.num_workers * self.prefetch_factor)
        self._stopped = False
        self._pool = mp.get_context("fork").Pool(self.num_workers)

    def request_drain(self) -> None:
        self._indices.drain()

    def _bounded_indices(self):
        """Yield index-batches to the pool's task pump, at most
        num_workers * prefetch_factor ahead of the consumer."""
        for idx in self._indices:
            self._inflight.acquire()
            if self._stopped:   # shutdown() released us just to unblock
                return
            yield idx

    def __iter__(self):
        import functools
        fn = functools.partial(_mp_get_batch, self.dataset, self.fast)
        try:
            for batch in self._pool.imap(
                    fn, self._bounded_indices(),
                    chunksize=1):
                try:
                    self._inflight.release()
                except ValueError:      # pragma: no cover - defensive
                    pass
                nbytes = batch_nbytes(batch)
                self.monitor.reserve(nbytes)
                self.monitor.release(nbytes)
                yield batch
        finally:
            self.shutdown()

    def shutdown(self):
        # Pool.terminate() joins the task-pump thread, which may be parked
        # in _bounded_indices' semaphore acquire if the consumer quit early
        # — unblock it first or terminate() never returns.
        self._stopped = True
        while True:
            try:
                self._inflight.release()
            except ValueError:          # back at the bound: pump is awake
                break
        self._pool.terminate()
