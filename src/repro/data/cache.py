"""Cross-epoch host-level cache tier (DESIGN.md §7).

``CacheTier`` retains raw storage items across epochs inside a hard byte
budget so epochs 2+ stream at memory speed instead of re-paying cold IO.
Admission is *deterministic*: the tier derives a hot set — the first
``hot_chunks`` locality chunks of the index space — purely from
``(budget_bytes, chunk, num_items, mean item bytes)``, so every host of a
fleet, a restored checkpoint, and a resharded stream all converge on the
same resident set without coordination.  That same ``hot_chunks`` count is
what ``ShardedSampler.set_cache_plan`` uses to interleave cached chunks
with cold ones in the epoch permutation, which is what lets the prefetcher
fill misses while hits are consumed.

``CachedStorage`` is the read-path adapter: a ``Storage``-shaped view over
an inner storage that serves hits from the tier and (optionally) admits
misses.  Trials use ``admit=False`` views (or throwaway tiers) so
measurement never pollutes the live cache.

Budget accounting: the tier can be handed an ``arena_bytes`` callable
(late-bound to the loader's persistent slab arena) whose current usage is
deducted from the effective budget, so arena + cache share one memory
budget without double-counting (see ``SlabArena.nbytes_in_use``).
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.storage import Storage


def plan_hot_chunks(budget_bytes: int, chunk: int, num_items: int,
                    item_nbytes: float) -> int:
    """Number of leading index-space chunks that fit in ``budget_bytes``.

    Deterministic in its scalar inputs — every host computes the same plan
    from the same (budget, chunk, dataset) triple, no coordination needed.
    """
    if budget_bytes <= 0 or num_items <= 0 or item_nbytes <= 0:
        return 0
    chunk = max(1, int(chunk))
    n_chunks = -(-num_items // chunk)
    per_chunk = chunk * float(item_nbytes)
    return max(0, min(n_chunks, int(budget_bytes // per_chunk)))


class CacheTier:
    """Budget-bounded, index-keyed raw-item cache with a deterministic
    hot-set admission filter.

    Items are bucketed by locality chunk id (``index // chunk``); only
    indices inside the hot set (chunk ids ``< hot_chunks``) are admitted,
    and eviction (needed only after a ``resize``/``reconfigure`` shrink)
    drops the *highest* resident chunk id first — so after any one full
    epoch the resident set equals the hot set exactly, regardless of
    consumption order.
    """

    def __init__(self, budget_bytes: int, *, chunk: int = 1,
                 num_items: int = 0, item_nbytes: float = 0.0,
                 arena_bytes: Optional[Callable[[], int]] = None):
        self._lock = threading.Lock()
        self._arena_bytes = arena_bytes
        self._items: Dict[int, np.ndarray] = {}
        self._chunk_bytes: Dict[int, int] = {}
        self._nbytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # brownout degraded mode (DESIGN.md §10): when the loader's fault
        # rate crosses its threshold, the tier stops admitting new items —
        # serve-hits-first read-only mode — so a failing backend can't
        # churn the resident set it is about to depend on
        self.read_only = False
        self._configure(budget_bytes, chunk, num_items, item_nbytes)

    # -- configuration -----------------------------------------------------
    def _configure(self, budget_bytes, chunk, num_items, item_nbytes):
        self.budget_bytes = max(0, int(budget_bytes))
        self.chunk = max(1, int(chunk))
        self.num_items = int(num_items)
        self.item_nbytes = float(item_nbytes)
        self.hot_chunks = plan_hot_chunks(
            self.budget_bytes, self.chunk, self.num_items, self.item_nbytes)

    def reconfigure(self, *, budget_bytes: Optional[int] = None,
                    chunk: Optional[int] = None,
                    num_items: Optional[int] = None,
                    item_nbytes: Optional[float] = None) -> None:
        """Re-spec the tier in place (hot-swap / reshard path): recompute
        the hot set and evict whatever fell out of it.  Entries that stay
        hot are kept — a resize is a trim, never a flush."""
        with self._lock:
            self._configure(
                self.budget_bytes if budget_bytes is None else budget_bytes,
                self.chunk if chunk is None else chunk,
                self.num_items if num_items is None else num_items,
                self.item_nbytes if item_nbytes is None else item_nbytes)
            self._evict_over_budget()

    def resize(self, budget_bytes: int) -> None:
        self.reconfigure(budget_bytes=budget_bytes)

    # -- accounting --------------------------------------------------------
    def nbytes_in_use(self) -> int:
        with self._lock:
            return self._nbytes

    def _effective_budget(self) -> int:
        eff = self.budget_bytes
        if self._arena_bytes is not None:
            try:
                eff -= max(0, int(self._arena_bytes()))
            except Exception:
                pass
        return max(0, eff)

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {"cache_tier_hits": self.hits,
                    "cache_tier_misses": self.misses,
                    "cache_tier_items": len(self._items),
                    "cache_tier_bytes": self._nbytes}

    # -- data path ---------------------------------------------------------
    def lookup(self, indices: Sequence[int]
               ) -> Tuple[Dict[int, np.ndarray], List[int]]:
        """Partition ``indices`` into served hits and missing indices."""
        hits: Dict[int, np.ndarray] = {}
        missing: List[int] = []
        with self._lock:
            for i in indices:
                item = self._items.get(int(i))
                if item is None:
                    missing.append(int(i))
                    self.misses += 1
                else:
                    hits[int(i)] = item
                    self.hits += 1
        return hits, missing

    def admit(self, index: int, item: np.ndarray) -> bool:
        """Insert ``item`` if its chunk is hot and the budget allows."""
        cid = int(index) // self.chunk
        if cid >= self.hot_chunks:
            return False
        nbytes = int(getattr(item, "nbytes", 0) or 0)
        with self._lock:
            if int(index) in self._items:
                return True
            if self._nbytes + nbytes > self._effective_budget():
                return False
            self._items[int(index)] = item
            self._chunk_bytes[cid] = self._chunk_bytes.get(cid, 0) + nbytes
            self._nbytes += nbytes
            assert self._nbytes <= self.budget_bytes, \
                (self._nbytes, self.budget_bytes)
            return True

    def _evict_over_budget(self) -> None:
        # caller holds the lock; drop highest chunk ids until both the
        # hot-set filter and the budget are satisfied again
        while self._chunk_bytes:
            top = max(self._chunk_bytes)
            if top < self.hot_chunks and self._nbytes <= self.budget_bytes:
                break
            lo, hi = top * self.chunk, (top + 1) * self.chunk
            for i in range(lo, hi):
                item = self._items.pop(i, None)
                if item is not None:
                    self.evictions += 1
            self._nbytes -= self._chunk_bytes.pop(top)
        if not self._chunk_bytes:
            self._nbytes = 0

    def clear(self) -> None:
        with self._lock:
            self._items.clear()
            self._chunk_bytes.clear()
            self._nbytes = 0


class CachedStorage(Storage):
    """Storage view that serves reads through a ``CacheTier``.

    Deliberately does *not* forward the inner storage's io-counter fields:
    ``DataLoader.io_counters()`` keeps reading the unwrapped storage for
    IO truth, and tier hit/miss counters are reported separately — hits
    never reach the inner storage at all, which is the point.
    """

    def __init__(self, inner: Storage, tier: CacheTier, *,
                 admit: bool = True):
        self.inner = inner
        self.tier = tier
        self.admit = admit

    def __len__(self) -> int:
        return len(self.inner)

    def item_nbytes(self, index: int) -> int:
        return self.inner.item_nbytes(index)

    def profile(self, **kw):
        return self.inner.profile(**kw)

    def read(self, index: int) -> np.ndarray:
        hits, missing = self.tier.lookup([index])
        if not missing:
            return hits[int(index)]
        item = self.inner.read(index)
        if self.admit and not self.tier.read_only:
            self.tier.admit(index, item)
        return item

    def read_batch(self, indices: Sequence[int]) -> List[np.ndarray]:
        idx = [int(i) for i in indices]
        hits, missing = self.tier.lookup(idx)
        if missing:
            fetched = self.inner.read_batch(missing)
            admit = self.admit and not self.tier.read_only
            for i, item in zip(missing, fetched):
                hits[i] = item
                if admit:
                    self.tier.admit(i, item)
        return [hits[i] for i in idx]
