"""Deterministic, checkpointable, host-sharded batch sampler.

Multi-pod semantics: every host sees the same global permutation (seeded by
(seed, epoch)) and takes a strided shard of each global batch, so the fleet
consumes a consistent global batch without coordination.  The sampler state
(epoch, offset) is part of the training checkpoint — restart resumes the
data stream exactly (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class SamplerState:
    epoch: int = 0
    batch_offset: int = 0

    def to_dict(self):
        return {"epoch": self.epoch, "batch_offset": self.batch_offset}

    @classmethod
    def from_dict(cls, d):
        return cls(epoch=int(d["epoch"]), batch_offset=int(d["batch_offset"]))

    def advanced(self, n: int, batches_per_epoch: int) -> "SamplerState":
        """State after consuming n more batches.  Checkpoints must record
        the CONSUMER's position, not the producer's (workers + prefetch run
        ahead of the train loop)."""
        total = self.epoch * batches_per_epoch + self.batch_offset + n
        return SamplerState(total // batches_per_epoch,
                            total % batches_per_epoch)

    def absolute(self, batches_per_epoch: int) -> int:
        """Position as a single global batch count since step 0."""
        return self.epoch * batches_per_epoch + self.batch_offset

    @classmethod
    def from_absolute(cls, position: int,
                      batches_per_epoch: int) -> "SamplerState":
        return cls(position // batches_per_epoch,
                   position % batches_per_epoch)


class ShardedSampler:
    def __init__(self, num_items: int, global_batch: int, *,
                 shuffle: bool = True, seed: int = 0, drop_last: bool = True,
                 host_index: int = 0, host_count: int = 1,
                 state: Optional[SamplerState] = None):
        if global_batch % host_count:
            raise ValueError(
                f"global_batch {global_batch} not divisible by host_count "
                f"{host_count}")
        self.num_items = num_items
        self.global_batch = global_batch
        self.local_batch = global_batch // host_count
        self.shuffle = shuffle
        self.seed = seed
        self.drop_last = drop_last
        self.host_index = host_index
        self.host_count = host_count
        self.state = state or SamplerState()

    def batches_per_epoch(self) -> int:
        if self.drop_last:
            return self.num_items // self.global_batch
        return -(-self.num_items // self.global_batch)

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        if not self.shuffle:
            return np.arange(self.num_items)
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self.num_items)

    def local_indices(self, epoch: int, batch: int) -> np.ndarray:
        """This host's slice of global batch ``batch`` in ``epoch``."""
        perm = self._epoch_perm(epoch)
        start = batch * self.global_batch
        glob = perm[start:start + self.global_batch]
        if len(glob) < self.global_batch and not self.drop_last:
            glob = np.concatenate([glob, perm[:self.global_batch - len(glob)]])
        return glob[self.host_index::self.host_count]

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            n = self.batches_per_epoch()
            while self.state.batch_offset < n:
                b = self.state.batch_offset
                self.state.batch_offset += 1
                yield self.local_indices(self.state.epoch, b)
            self.state.epoch += 1
            self.state.batch_offset = 0

    def epoch_iter(self, epoch: Optional[int] = None) -> Iterator[np.ndarray]:
        """One epoch, non-stateful (used by DPT trials)."""
        e = self.state.epoch if epoch is None else epoch
        for b in range(self.batches_per_epoch()):
            yield self.local_indices(e, b)

    # ---- elastic resharding -------------------------------------------------
    def reshard(self, num_shards: int, shard: int) -> None:
        """Remap this sampler's shard of the live stream (elastic fleet
        transition: a host died or joined).

        The global permutation and the global-batch boundaries depend only
        on (seed, epoch, global_batch) — never on the shard topology — so
        changing (shard, num_shards) at a global batch boundary re-slices
        every NOT-YET-DELIVERED global batch while leaving delivered ones
        untouched.  The union over the new shard set of any global batch is
        exactly that batch's indices, which is the zero-lost/zero-duplicated
        coverage invariant the fleet coordinator relies on.  The position
        (epoch, batch_offset) is in global batches and survives unchanged.
        """
        if not 0 <= shard < num_shards:
            raise ValueError(f"shard {shard} out of range for "
                             f"{num_shards} shards")
        if self.global_batch % num_shards:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by "
                f"num_shards {num_shards}")
        self.host_count = num_shards
        self.host_index = shard
        self.local_batch = self.global_batch // num_shards
