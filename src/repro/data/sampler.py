"""Deterministic, checkpointable, host-sharded batch sampler.

Multi-pod semantics: every host sees the same global permutation (seeded by
(seed, epoch)) and takes a strided shard of each global batch, so the fleet
consumes a consistent global batch without coordination.  The sampler state
(epoch, offset) is part of the training checkpoint — restart resumes the
data stream exactly (fault-tolerance requirement).

IO locality (DESIGN.md §5): ``locality_chunk = C > 1`` switches the epoch
permutation from fully random to *chunked* — fixed-size contiguous chunks
of the index space are shuffled as units, and items are shuffled within
each chunk.  A batch then covers a few whole chunks instead of B scattered
items, so ``Storage.read_batch``'s sorted-miss coalescing sees contiguous
runs of ~C items (one storage request per run instead of one per item) on
cold epochs.  Coverage is untouched: a chunked order is still exactly a
permutation of [0, N), so once-per-epoch delivery — including under
mid-epoch ``reshard`` — holds unconditionally.  Locality changes are
epoch-latched (``set_locality``): an in-progress epoch keeps its order, so
a live hot swap can never split one epoch across two permutations.  A
coordinated fleet pins the latch epoch explicitly (``set_locality(chunk,
epoch=E)``) so every host adopts the new chunk for the SAME epoch even
when their producers straddle an epoch boundary.

Host layout (DESIGN.md §6): hosts take *contiguous* slices of each global
batch (``host_major``, the default) rather than strided ones.  Any
deterministic partition of the global batch preserves the coverage
invariant (the union over hosts is the batch either way), but striding
dilutes locality — each host gets every H-th element, shrinking per-host
coalesced runs toward C/H — while host-major slices keep whole chunks on
one host at any host count.  ``layout="strided"`` keeps the legacy
behavior for A/B measurement (bench_locality's multi-host gate).

Elastic geometry (DESIGN.md §11): the global batch itself is an
epoch-latched schedule (``set_geometry``), exactly like the locality and
cache-plan schedules — an in-progress epoch keeps its batch boundaries
(the stream position is counted in global batches, so moving a boundary
mid-epoch would re-partition batches that were already delivered), and
the new geometry applies from a pinned future epoch on every host at
once.  Within an epoch, hosts may take *non-uniform* contiguous slices
(``shard_sizes``): per-host sizes summing to the global batch, so a
reshard to a survivor count that does not divide the global batch can
finish the epoch with a ragged split instead of raising, and a per-host
consensus can hand fast hosts proportionally larger slices.  Sizes only
change the partition of each global batch — never the permutation or the
batch boundaries — so they may switch at any common batch barrier, while
geometry (which moves boundaries) must latch at an epoch boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class SamplerState:
    epoch: int = 0
    batch_offset: int = 0

    def to_dict(self):
        return {"epoch": self.epoch, "batch_offset": self.batch_offset}

    @classmethod
    def from_dict(cls, d):
        return cls(epoch=int(d["epoch"]), batch_offset=int(d["batch_offset"]))

    def advanced(self, n: int, batches_per_epoch: int) -> "SamplerState":
        """State after consuming n more batches.  Checkpoints must record
        the CONSUMER's position, not the producer's (workers + prefetch run
        ahead of the train loop)."""
        total = self.epoch * batches_per_epoch + self.batch_offset + n
        return SamplerState(total // batches_per_epoch,
                            total % batches_per_epoch)

    def absolute(self, batches_per_epoch: int) -> int:
        """Position as a single global batch count since step 0."""
        return self.epoch * batches_per_epoch + self.batch_offset

    @classmethod
    def from_absolute(cls, position: int,
                      batches_per_epoch: int) -> "SamplerState":
        return cls(position // batches_per_epoch,
                   position % batches_per_epoch)


class ShardedSampler:
    def __init__(self, num_items: int, global_batch: int, *,
                 shuffle: bool = True, seed: int = 0, drop_last: bool = True,
                 host_index: int = 0, host_count: int = 1,
                 state: Optional[SamplerState] = None,
                 locality_chunk: int = 0, layout: str = "host_major",
                 shard_sizes: Optional[Sequence[int]] = None):
        if layout not in ("host_major", "strided"):
            raise ValueError(f"unknown shard layout {layout!r}")
        if shard_sizes is not None:
            shard_sizes = tuple(int(s) for s in shard_sizes)
            if (len(shard_sizes) != host_count
                    or sum(shard_sizes) != global_batch
                    or min(shard_sizes) < 0):
                raise ValueError(
                    f"shard_sizes {shard_sizes} must be {host_count} "
                    f"non-negative sizes summing to {global_batch}")
        elif global_batch % host_count:
            raise ValueError(
                f"global_batch {global_batch} not divisible by host_count "
                f"{host_count} (pass shard_sizes= for a ragged split)")
        self.num_items = num_items
        # (first_epoch, global_batch) steps — same latch semantics as the
        # locality schedule.  ``global_batch`` / ``local_batch`` are
        # properties over this schedule at the current epoch.
        self._geometry_schedule: List[Tuple[int, int]] = [
            (0, int(global_batch))]
        self._shard_sizes: Optional[Tuple[int, ...]] = shard_sizes
        self.shuffle = shuffle
        self.seed = seed
        self.drop_last = drop_last
        self.host_index = host_index
        self.host_count = host_count
        self.state = state or SamplerState()
        self.layout = layout
        self.locality_chunk = max(0, int(locality_chunk))
        # (first_epoch, chunk) steps; the chunk for epoch e is the last
        # entry with first_epoch <= e — how set_locality defers a change
        # to the next epoch boundary without forgetting the old order
        self._locality_schedule: List[Tuple[int, int]] = [
            (0, self.locality_chunk)]
        # (first_epoch, hot_k) steps for the cache-aware order (DESIGN.md
        # §7): hot_k > 0 interleaves the first hot_k index-space chunks
        # (the cache tier's deterministic hot set) evenly among the cold
        # ones, so cached hits are consumed while the prefetcher fills
        # misses.  Same latch semantics as the locality schedule.
        self._cache_schedule: List[Tuple[int, int]] = [(0, 0)]
        self._perm_cache: dict = {}

    def batches_per_epoch(self, epoch: Optional[int] = None) -> int:
        return self._bpe_for(self.gb_for_epoch(
            self.state.epoch if epoch is None else epoch))

    def _bpe_for(self, global_batch: int) -> int:
        if self.drop_last:
            return self.num_items // global_batch
        return -(-self.num_items // global_batch)

    # ---- geometry schedule --------------------------------------------------
    @property
    def global_batch(self) -> int:
        return self.gb_for_epoch(self.state.epoch)

    @property
    def local_batch(self) -> int:
        return self.sizes_for_epoch(self.state.epoch)[self.host_index]

    @property
    def shard_sizes(self) -> Optional[Tuple[int, ...]]:
        return self._shard_sizes

    @staticmethod
    def even_split(total: int, parts: int) -> Tuple[int, ...]:
        """Largest-remainder split of ``total`` over ``parts`` hosts: the
        first ``total % parts`` hosts take one extra item, so sizes always
        sum to ``total`` — the ragged fallback when divisibility fails."""
        base, rem = divmod(int(total), int(parts))
        return tuple(base + (1 if i < rem else 0) for i in range(parts))

    def gb_for_epoch(self, epoch: int) -> int:
        """The global batch in effect for ``epoch``."""
        gb = self._geometry_schedule[0][1]
        for e, g in self._geometry_schedule:
            if e > epoch:
                break
            gb = g
        return gb

    def sizes_for_epoch(self, epoch: int) -> Tuple[int, ...]:
        """Per-host slice sizes of each global batch in ``epoch``.

        Explicit ``shard_sizes`` apply while they still sum to the epoch's
        global batch; once a geometry change makes them stale the split
        reverts to even (the coordinator re-pushes weighted sizes after a
        geometry latch if it still wants them)."""
        gb = self.gb_for_epoch(epoch)
        if (self._shard_sizes is not None
                and len(self._shard_sizes) == self.host_count
                and sum(self._shard_sizes) == gb):
            return self._shard_sizes
        return self.even_split(gb, self.host_count)

    def set_geometry(self, global_batch: int, *,
                     epoch: Optional[int] = None) -> int:
        """Change the global batch.  Epoch-latched exactly like
        ``set_locality`` — batch boundaries are position arithmetic, so an
        in-progress epoch must keep its geometry and a fleet pins one
        common latch epoch for every host.  Returns the effective first
        epoch of the new geometry."""
        global_batch = int(global_batch)
        if global_batch <= 0:
            raise ValueError(f"global_batch must be positive, "
                             f"got {global_batch}")
        eff = self.natural_latch_epoch()
        if epoch is not None:
            eff = max(eff, int(epoch))
        elif global_batch == self._geometry_schedule[-1][1]:
            return eff
        self._geometry_schedule = [
            (e, g) for e, g in self._geometry_schedule if e < eff]
        self._geometry_schedule.append((eff, global_batch))
        return eff

    def force_geometry(self, global_batch: int) -> None:
        """Reset the schedule to ``global_batch`` for every epoch
        (restore path)."""
        self._geometry_schedule = [(0, int(global_batch))]

    def geometry_state(self) -> List[List[int]]:
        return [[int(e), int(g)] for e, g in self._geometry_schedule]

    def load_geometry(self, schedule: Sequence[Sequence[int]]) -> None:
        self._geometry_schedule = [(int(e), int(g)) for e, g in schedule]

    # ---- schedule-aware absolute position ----------------------------------
    def epoch_start(self, epoch: int) -> int:
        """Absolute batch position where ``epoch`` starts.  With an
        elastic geometry schedule epochs have different lengths, so this
        walks the schedule instead of multiplying by a constant."""
        total = 0
        sched = self._geometry_schedule
        for i, (e0, gb) in enumerate(sched):
            if e0 >= epoch:
                break
            e1 = min(epoch,
                     sched[i + 1][0] if i + 1 < len(sched) else epoch)
            total += (e1 - e0) * self._bpe_for(gb)
        return total

    def absolute(self) -> int:
        """The current state's position as a single global batch count
        since step 0 (schedule-aware replacement for
        ``SamplerState.absolute``)."""
        return self.epoch_start(self.state.epoch) + self.state.batch_offset

    def state_at(self, position: int) -> SamplerState:
        """(epoch, batch_offset) for an absolute position under the
        geometry schedule (schedule-aware ``SamplerState.from_absolute``)."""
        pos = int(position)
        sched = self._geometry_schedule
        for i, (e0, gb) in enumerate(sched):
            bpe = self._bpe_for(gb)
            if i + 1 < len(sched):
                span = (sched[i + 1][0] - e0) * bpe
                if pos < span:
                    return SamplerState(e0 + pos // bpe, pos % bpe)
                pos -= span
            else:
                return SamplerState(e0 + pos // bpe, pos % bpe)
        raise AssertionError("unreachable: schedule is never empty")

    def latch_epoch_for(self, position: int) -> int:
        """First epoch whose start is at or after ``position`` — where a
        producer that has run ahead to ``position`` could first adopt a
        new permutation or geometry."""
        st = self.state_at(position)
        return st.epoch + (1 if st.batch_offset else 0)

    # ---- locality schedule ------------------------------------------------
    def chunk_for_epoch(self, epoch: int) -> int:
        """The locality_chunk in effect for ``epoch``."""
        chunk = self._locality_schedule[0][1]
        for e, c in self._locality_schedule:
            if e > epoch:
                break
            chunk = c
        return chunk

    def natural_latch_epoch(self) -> int:
        """The first epoch a locality change could take effect for: the
        current epoch if it has not produced a batch yet, else the next."""
        return self.state.epoch + (1 if self.state.batch_offset else 0)

    def set_locality(self, chunk: int, *, epoch: Optional[int] = None) -> int:
        """Change the chunked-shuffle granularity (0/1 = fully random).

        Epoch-latched: takes effect for the current epoch only if it has
        not delivered a batch yet, otherwise from the next epoch — an
        in-progress epoch keeps its permutation, so coverage stays exact
        across a live hot swap.  ``epoch`` pins the latch explicitly (a
        fleet coordinator pushes one common epoch to every host so the
        whole fleet adopts the new chunk for the SAME epoch); it is
        clamped up to this sampler's natural latch epoch, never down —
        an epoch that already produced batches keeps its order.  Returns
        the effective first epoch of the new chunk.
        """
        chunk = max(0, int(chunk))
        eff = self.natural_latch_epoch()
        if epoch is not None:
            eff = max(eff, int(epoch))
        elif chunk == self.locality_chunk:
            return eff
        self.locality_chunk = chunk
        # epochs >= eff follow the new chunk; earlier epochs keep whatever
        # was scheduled (they may already have produced batches)
        self._locality_schedule = [
            (e, c) for e, c in self._locality_schedule if e < eff]
        self._locality_schedule.append((eff, chunk))
        return eff

    def force_locality(self, chunk: int) -> None:
        """Reset the schedule to ``chunk`` for every epoch (restore path)."""
        self.locality_chunk = max(0, int(chunk))
        self._locality_schedule = [(0, self.locality_chunk)]

    def locality_state(self) -> List[List[int]]:
        return [[int(e), int(c)] for e, c in self._locality_schedule]

    def load_locality(self, schedule: Sequence[Sequence[int]]) -> None:
        self._locality_schedule = [(int(e), int(c)) for e, c in schedule]
        self.locality_chunk = self._locality_schedule[-1][1]

    # ---- cache plan --------------------------------------------------------
    @property
    def cache_hot_chunks(self) -> int:
        return self._cache_schedule[-1][1]

    def hot_k_for_epoch(self, epoch: int) -> int:
        """The cache hot-chunk count in effect for ``epoch``."""
        hot_k = self._cache_schedule[0][1]
        for e, k in self._cache_schedule:
            if e > epoch:
                break
            hot_k = k
        return hot_k

    def set_cache_plan(self, hot_k: int, *,
                       epoch: Optional[int] = None) -> int:
        """Change the cache-aware interleave (0 = plan-blind order).

        Epoch-latched exactly like ``set_locality`` — the plan changes the
        epoch permutation, so an in-progress epoch must keep its order and
        a fleet pins one common latch epoch for every host.  Returns the
        effective first epoch of the new plan."""
        hot_k = max(0, int(hot_k))
        eff = self.natural_latch_epoch()
        if epoch is not None:
            eff = max(eff, int(epoch))
        elif hot_k == self.cache_hot_chunks:
            return eff
        self._cache_schedule = [
            (e, k) for e, k in self._cache_schedule if e < eff]
        self._cache_schedule.append((eff, hot_k))
        return eff

    def force_cache_plan(self, hot_k: int) -> None:
        """Reset the plan to ``hot_k`` for every epoch (restore path)."""
        self._cache_schedule = [(0, max(0, int(hot_k)))]

    def cache_state(self) -> List[List[int]]:
        return [[int(e), int(k)] for e, k in self._cache_schedule]

    def load_cache_plan(self, schedule: Sequence[Sequence[int]]) -> None:
        self._cache_schedule = [(int(e), int(k)) for e, k in schedule]

    # ---- epoch orders -----------------------------------------------------
    @staticmethod
    def _chunked_perm(rng: np.random.Generator, n: int,
                      chunk: int) -> np.ndarray:
        """Shuffle contiguous ``chunk``-sized blocks of [0, n) as units,
        and shuffle within each block.  Still exactly a permutation of
        [0, n), so coverage never depends on the chunk size."""
        n_chunks = -(-n // chunk)
        order = rng.permutation(n_chunks)
        keys = rng.random((n_chunks, chunk))
        base = np.arange(n_chunks * chunk).reshape(n_chunks, chunk)
        within = np.take_along_axis(base, np.argsort(keys, axis=1), axis=1)
        perm = within[order].reshape(-1)
        # the padded tail chunk carries out-of-range slots: drop them
        return perm[perm < n] if n_chunks * chunk != n else perm

    @staticmethod
    def _interleaved_perm(rng: np.random.Generator, n: int, chunk: int,
                          hot_k: int) -> np.ndarray:
        """Chunked permutation whose first ``hot_k`` index-space chunks
        (the cache tier's hot set) land at evenly spaced positions among
        the cold chunks: cached hits are consumed throughout the epoch
        while the prefetcher fills the cold misses between them.  Hot and
        cold chunks are each shuffled, so this is still exactly a
        permutation of [0, n) — coverage is untouched."""
        n_chunks = -(-n // chunk)
        hot_k = min(hot_k, n_chunks)
        hot = rng.permutation(hot_k)
        cold = hot_k + rng.permutation(n_chunks - hot_k)
        order = np.empty(n_chunks, dtype=np.int64)
        pos = (np.arange(hot_k) * n_chunks) // hot_k
        mask = np.zeros(n_chunks, dtype=bool)
        mask[pos] = True
        order[pos] = hot
        order[~mask] = cold
        keys = rng.random((n_chunks, chunk))
        base = np.arange(n_chunks * chunk).reshape(n_chunks, chunk)
        within = np.take_along_axis(base, np.argsort(keys, axis=1), axis=1)
        perm = within[order].reshape(-1)
        return perm[perm < n] if n_chunks * chunk != n else perm

    def _epoch_perm(self, epoch: int,
                    chunk: Optional[int] = None) -> np.ndarray:
        if not self.shuffle:
            return np.arange(self.num_items)
        if chunk is None:
            chunk = self.chunk_for_epoch(epoch)
            hot_k = self.hot_k_for_epoch(epoch)
        else:
            # explicit override = a DPT trial measuring a candidate chunk:
            # plan-blind, so trials never depend on the live cache plan
            hot_k = 0
        chunk = max(0, int(chunk))
        if chunk <= 1:
            hot_k = 0   # a fully random order already interleaves hot/cold
        key = (epoch, chunk, hot_k, self.seed, self.num_items)
        perm = self._perm_cache.get(key)
        if perm is None:
            rng = np.random.default_rng((self.seed, epoch))
            if chunk <= 1:
                perm = rng.permutation(self.num_items)
            elif hot_k > 0:
                perm = self._interleaved_perm(rng, self.num_items, chunk,
                                              hot_k)
            else:
                perm = self._chunked_perm(rng, self.num_items, chunk)
            if len(self._perm_cache) >= 4:   # tiny memo: streams touch at
                self._perm_cache.clear()     # most a couple of epochs at once
            self._perm_cache[key] = perm
        return perm

    def local_indices(self, epoch: int, batch: int,
                      chunk: Optional[int] = None) -> np.ndarray:
        """This host's slice of global batch ``batch`` in ``epoch``.

        ``chunk`` overrides the scheduled locality for this lookup only
        (DPT trials measure candidate chunk sizes without touching the
        live schedule).
        """
        perm = self._epoch_perm(epoch, chunk)
        gb = self.gb_for_epoch(epoch)
        start = batch * gb
        glob = perm[start:start + gb]
        if len(glob) < gb and not self.drop_last:
            glob = np.concatenate([glob, perm[:gb - len(glob)]])
        if self.layout == "strided":
            return glob[self.host_index::self.host_count]
        # host-major: contiguous slice — whole chunks of a chunked perm
        # stay on one host (strided slices dilute runs toward C/H).  Both
        # layouts partition the global batch, so coverage is identical —
        # including under non-uniform sizes, whose prefix-sum offsets
        # still tile the batch exactly.
        sizes = self.sizes_for_epoch(epoch)
        off = sum(sizes[:self.host_index])
        return glob[off:off + sizes[self.host_index]]

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            n = self.batches_per_epoch(self.state.epoch)
            while self.state.batch_offset < n:
                b = self.state.batch_offset
                self.state.batch_offset += 1
                yield self.local_indices(self.state.epoch, b)
            self.state.epoch += 1
            self.state.batch_offset = 0

    def epoch_iter(self, epoch: Optional[int] = None,
                   chunk: Optional[int] = None) -> Iterator[np.ndarray]:
        """One epoch, non-stateful (used by DPT trials).  ``chunk``
        overrides the scheduled locality for this iteration only."""
        e = self.state.epoch if epoch is None else epoch
        for b in range(self.batches_per_epoch(e)):
            yield self.local_indices(e, b, chunk)

    # ---- elastic resharding -------------------------------------------------
    def reshard(self, num_shards: int, shard: int, *,
                sizes: Optional[Sequence[int]] = None) -> None:
        """Remap this sampler's shard of the live stream (elastic fleet
        transition: a host died or joined, or the coordinator re-weighted
        the per-host split).

        The global permutation and the global-batch boundaries depend only
        on (seed, epoch, global_batch) — never on the shard topology — so
        changing (shard, num_shards) at a global batch boundary re-slices
        every NOT-YET-DELIVERED global batch while leaving delivered ones
        untouched.  The union over the new shard set of any global batch is
        exactly that batch's indices, which is the zero-lost/zero-duplicated
        coverage invariant the fleet coordinator relies on.  The position
        (epoch, batch_offset) is in global batches and survives unchanged.

        ``sizes`` gives an explicit per-shard split of the current epoch's
        global batch (ragged survivor counts, per-host consensus weights).
        Without it the split must be uniform, and a non-divisible count
        raises rather than silently truncating.
        """
        if not 0 <= shard < num_shards:
            raise ValueError(f"shard {shard} out of range for "
                             f"{num_shards} shards")
        if sizes is not None:
            sizes = tuple(int(s) for s in sizes)
            if (len(sizes) != num_shards or sum(sizes) != self.global_batch
                    or min(sizes) < 0):
                raise ValueError(
                    f"sizes {sizes} must be {num_shards} non-negative "
                    f"sizes summing to {self.global_batch}")
        elif self.global_batch % num_shards:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by "
                f"num_shards {num_shards} (pass sizes= for a ragged split)")
        self.host_count = num_shards
        self.host_index = shard
        self._shard_sizes = sizes
