"""Fault-tolerant data plane (DESIGN.md §10).

PR 7 made the fleet control plane survive partitions and coordinator
death; this module does the same for the path that actually moves bytes.
Four pieces, composed by the worker pools and the loader:

* ``FaultyStorage`` — a seeded, picklable wrapper injecting transient
  ``IOError``s, permanent per-item corruption, latency spikes and timed
  brownout windows into ANY backend's ``read``/``read_batch``.  The
  data-path twin of ``FaultyTransport``: every draw is a pure
  ``splitmix64`` hash, so faults are identical across threads, processes
  and reruns.
* ``RetryPolicy`` — bounded attempts, exponential backoff with
  deterministic jitter, and a per-read deadline.  Attempts bound
  *per-item* transients; the deadline bounds *storage-wide* outages
  (``BrownoutError``), which no per-item budget should count against.
* ``QuarantineLog`` — items that exhausted their retries (or are
  permanently corrupt).  Checkpointable: rides ``DataLoader.state_dict``
  like the cost tracker, so a restored loader keeps skipping known-bad
  ids.
* ``FaultPolicy`` — the bundle a worker-pool task body runs reads
  through: screen quarantined ids, retry transients, attribute failures
  to items (probing one-by-one when the error is unattributed), then
  complete the batch under the declared ``on_bad_sample`` policy:

  - ``"raise"``       — legacy pool-fatal behavior (still the default);
  - ``"skip"``        — drop the bad ids; the delivered multiset is
    provably the epoch permutation minus the quarantined ids;
  - ``"substitute"``  — deterministically resample replacements from the
    non-quarantined population, preserving batch count and size.

``FaultStats`` keeps the health counters (``read_retries``,
``read_faults``, ``resubmits``, windowed ``fault_rate``) and drives the
degraded-mode hysteresis: when the recent fault rate crosses the
threshold the loader flips its cache tier to serve-hits-first read-only
mode, and flips it back once the storage heals.  The counters flow
through ``TransferStats`` → ``io_counters()`` → fleet ``HostReport.io``,
where ``OnlineTuner.fault_rate_trigger`` / ``FleetConfig.
fault_rate_trigger`` turn them into automatic retune/recovery.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.data.storage import (BrownoutError, CorruptSampleError,
                                SampleReadError, Storage, TransientReadError,
                                splitmix_u01)

_BAD_SAMPLE_POLICIES = ("raise", "skip", "substitute")


# --------------------------------------------------------------------------
# fault injection
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StorageFaultSpec:
    """What ``FaultyStorage`` injects.  ``transient_rate`` is drawn per
    (item, failure-count) — retries deterministically clear; corruption
    (``corrupt_rate`` / explicit ``corrupt_items``) is permanent per item;
    ``brownout=(start, stop)`` fails every request while the wrapper's
    access clock is inside the window; ``spike_rate`` items sleep an extra
    ``spike_s`` per request (latency fault, not an error)."""
    transient_rate: float = 0.0
    corrupt_rate: float = 0.0
    corrupt_items: Tuple[int, ...] = ()
    spike_rate: float = 0.0
    spike_s: float = 0.0
    brownout: Optional[Tuple[int, int]] = None     # [start, stop) accesses
    seed: int = 0


class FaultyStorage(Storage):
    """Seeded fault-injecting wrapper over any ``Storage`` backend — the
    data-path twin of ``FaultyTransport``.  Picklable (locks remint on
    arrival), deterministic (pure-hash draws), and transparent on the
    happy path: batched reads forward to ``inner.read_batch`` so the
    wrapped backend's coalescing still happens."""

    def __init__(self, inner: Storage,
                 spec: StorageFaultSpec = StorageFaultSpec()):
        self.inner = inner
        self.spec = spec
        self._lock = threading.Lock()
        self._accesses = 0
        self._attempts: Dict[int, int] = {}     # idx -> transient failures
        self.transient_raised = 0
        self.corrupt_raised = 0
        self.brownout_raised = 0
        self.spikes_injected = 0

    def __len__(self):
        return len(self.inner)

    def item_nbytes(self, idx):
        return self.inner.item_nbytes(idx)

    def is_corrupt(self, idx: int) -> bool:
        s = self.spec
        if int(idx) in s.corrupt_items:
            return True
        return s.corrupt_rate > 0.0 \
            and splitmix_u01(s.seed, idx, 3) < s.corrupt_rate

    def _check(self, indices) -> None:
        s = self.spec
        with self._lock:
            self._accesses += 1
            clock = self._accesses
        for i in indices:
            if self.is_corrupt(i):
                with self._lock:
                    self.corrupt_raised += 1
                raise CorruptSampleError(
                    f"permanently corrupt item {int(i)}", index=int(i))
        if s.brownout is not None \
                and s.brownout[0] <= clock - 1 < s.brownout[1]:
            with self._lock:
                self.brownout_raised += 1
            raise BrownoutError(
                f"storage brownout (access {clock} in "
                f"window {s.brownout})")
        if s.transient_rate > 0.0:
            for i in indices:
                with self._lock:
                    attempt = self._attempts.get(int(i), 0)
                if splitmix_u01(s.seed, i,
                                101 + attempt) < s.transient_rate:
                    with self._lock:
                        self._attempts[int(i)] = attempt + 1
                        self.transient_raised += 1
                    raise TransientReadError(
                        f"transient fault on item {int(i)} "
                        f"(attempt {attempt})", index=int(i))
        if s.spike_rate > 0.0 and s.spike_s > 0.0:
            if any(splitmix_u01(s.seed, i, 5) < s.spike_rate
                   for i in indices):
                with self._lock:
                    self.spikes_injected += 1
                time.sleep(s.spike_s)

    def read(self, idx):
        self._check((int(idx),))
        return self.inner.read(idx)

    def read_batch(self, indices):
        self._check([int(i) for i in indices])
        return self.inner.read_batch(indices)

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {"transient_raised": self.transient_raised,
                    "corrupt_raised": self.corrupt_raised,
                    "brownout_raised": self.brownout_raised,
                    "spikes_injected": self.spikes_injected,
                    "accesses": self._accesses}

    def __getstate__(self):
        with self._lock:
            state = self.__dict__.copy()
            state["_attempts"] = dict(self._attempts)
        state["_lock"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()


# --------------------------------------------------------------------------
# retry policy
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff + deterministic jitter.

    ``attempts`` counts *retries after the first try* for item-attributed
    transients; ``deadline_s`` bounds the whole read including storage-wide
    brownouts (which never consume per-item attempts — see
    ``FaultPolicy.get_batch``)."""
    attempts: int = 2
    backoff_s: float = 0.01
    backoff_mult: float = 2.0
    backoff_max_s: float = 0.25
    jitter: float = 0.5
    deadline_s: float = 2.0
    seed: int = 0

    def sleep_s(self, retry: int, key: int = 0) -> float:
        """Backoff before the ``retry``-th re-attempt (1-based), jittered
        deterministically by (seed, key, retry)."""
        base = min(self.backoff_max_s,
                   self.backoff_s * self.backoff_mult ** max(0, retry - 1))
        if self.jitter <= 0.0:
            return base
        u = splitmix_u01(self.seed, key, 211 + retry)
        return base * (1.0 - self.jitter / 2.0 + self.jitter * u)


# --------------------------------------------------------------------------
# quarantine
# --------------------------------------------------------------------------
class QuarantineLog:
    """Items withdrawn from service, with reasons.  Checkpointable and
    mergeable (process-pool children ship deltas back to the parent)."""

    def __init__(self):
        self._items: Dict[int, str] = {}
        self._lock = threading.Lock()

    def add(self, idx: int, reason: str) -> bool:
        """Record one id; True when it was not already quarantined."""
        with self._lock:
            if int(idx) in self._items:
                return False
            self._items[int(idx)] = str(reason)
            return True

    def __contains__(self, idx) -> bool:
        with self._lock:
            return int(idx) in self._items

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def ids(self) -> np.ndarray:
        with self._lock:
            return np.array(sorted(self._items), dtype=np.intp)

    def reasons(self) -> Dict[int, str]:
        with self._lock:
            return dict(self._items)

    def state_dict(self) -> dict:
        with self._lock:
            return {"items": sorted(self._items.items())}

    def load_state_dict(self, d: dict) -> None:
        with self._lock:
            self._items = {int(i): str(r) for i, r in d.get("items", [])}

    def __getstate__(self):
        with self._lock:
            state = self.__dict__.copy()
            state["_items"] = dict(self._items)
        state["_lock"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()


# --------------------------------------------------------------------------
# health counters + degraded-mode hysteresis
# --------------------------------------------------------------------------
class FaultStats:
    """Cumulative fault counters plus a windowed fault rate driving the
    degraded-mode flip: enter when the recent rate reaches
    ``degraded_enter`` (with at least ``min_events`` observations), exit
    when successes dilute it back below a quarter of that.  The
    ``on_degraded(bool)`` callback fires on each transition — the loader
    wires it to the cache tier's read-only switch."""

    WINDOW = 64
    MIN_EVENTS = 8

    def __init__(self, *, degraded_enter: float = 0.5,
                 on_degraded: Optional[Callable[[bool], None]] = None):
        self.degraded_enter = max(0.0, degraded_enter)
        self.on_degraded = on_degraded
        self._lock = threading.Lock()
        self._window: deque = deque(maxlen=self.WINDOW)  # 1=fault, 0=ok
        self.read_retries = 0
        self.read_faults = 0
        self.resubmits = 0
        self.degraded = False
        self.degraded_enters = 0

    def fault_rate(self) -> float:
        with self._lock:
            return (sum(self._window) / len(self._window)
                    if self._window else 0.0)

    def _note(self, outcome: int) -> None:
        fire: Optional[bool] = None
        with self._lock:
            self._window.append(outcome)
            if self.degraded_enter > 0.0 \
                    and len(self._window) >= self.MIN_EVENTS:
                rate = sum(self._window) / len(self._window)
                if not self.degraded and rate >= self.degraded_enter:
                    self.degraded = True
                    self.degraded_enters += 1
                    fire = True
                elif self.degraded and rate <= self.degraded_enter / 4.0:
                    self.degraded = False
                    fire = False
        if fire is not None and self.on_degraded is not None:
            self.on_degraded(fire)

    def note_ok(self) -> None:
        self._note(0)

    def note_fault(self) -> None:
        with self._lock:
            self.read_faults += 1
        self._note(1)

    def note_retry(self) -> None:
        with self._lock:
            self.read_retries += 1

    def note_resubmit(self, n: int = 1) -> None:
        with self._lock:
            self.resubmits += n

    def merge_report(self, report: dict) -> None:
        """Fold a process-pool child's per-task tally into the live stats
        (children run on fork-copied stats; deltas ship back)."""
        with self._lock:
            self.read_retries += int(report.get("retries", 0))
            self.read_faults += int(report.get("faults", 0))
        for _ in range(int(report.get("faults", 0))):
            self._note(1)
        for _ in range(int(report.get("ok", 0))):
            self._note(0)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return {"read_retries": float(self.read_retries),
                    "read_faults": float(self.read_faults),
                    "resubmits": float(self.resubmits),
                    "degraded": 1.0 if self.degraded else 0.0}

    # callback and lock stay on the parent; forked/pickled copies tally
    # into a report instead
    def __getstate__(self):
        with self._lock:
            state = self.__dict__.copy()
            state["_window"] = deque(self._window, maxlen=self.WINDOW)
        state["_lock"] = None
        state["on_degraded"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()


# --------------------------------------------------------------------------
# the policy the worker pools run reads through
# --------------------------------------------------------------------------
class FaultPolicy:
    """Resilient ``get_batch``: screen quarantined ids, retry transients,
    quarantine what exhausts its budget, and complete the batch under the
    declared ``on_bad_sample`` policy.  One instance is shared by every
    worker thread (the log and stats are lock-guarded); process-pool tasks
    pickle a snapshot and ship their deltas back via ``report``."""

    def __init__(self, *, retry: RetryPolicy = RetryPolicy(),
                 quarantine: Optional[QuarantineLog] = None,
                 stats: Optional[FaultStats] = None,
                 on_bad_sample: str = "raise", num_items: int = 0,
                 seed: int = 0,
                 on_quarantine: Optional[
                     Callable[[List[int]], None]] = None):
        if on_bad_sample not in _BAD_SAMPLE_POLICIES:
            raise ValueError(
                f"on_bad_sample must be one of {_BAD_SAMPLE_POLICIES}, "
                f"got {on_bad_sample!r}")
        self.retry = retry
        # NOT `quarantine or ...`: an EMPTY log is falsy (__len__) but
        # still the caller's live log
        self.quarantine = QuarantineLog() if quarantine is None \
            else quarantine
        self.stats = FaultStats() if stats is None else stats
        self.on_bad_sample = on_bad_sample
        self.num_items = int(num_items)
        self.seed = int(seed)
        self.on_quarantine = on_quarantine

    # ---- quarantine bookkeeping -------------------------------------------
    def _quarantine(self, bad: Dict[int, str],
                    report: Optional[dict]) -> None:
        newly = [i for i, reason in sorted(bad.items())
                 if self.quarantine.add(i, reason)]
        if report is not None and newly:
            report.setdefault("quarantined", []).extend(
                (i, bad[i]) for i in newly)
        if newly and self.on_quarantine is not None:
            self.on_quarantine(newly)

    def _substitute_for(self, bad_idx: int, taken: set) -> Optional[int]:
        """Deterministic replacement drawn uniformly from the
        non-quarantined population (the same shard distribution the
        sampler draws from — coverage stays audit-friendly)."""
        if self.num_items <= 0:
            return None
        for k in range(64):
            cand = int(splitmix_u01(self.seed, bad_idx, 301 + k)
                       * self.num_items)
            if cand not in taken and cand not in self.quarantine:
                return cand
        return None

    def _apply_policy(self, idx: np.ndarray, bad: Dict[int, str],
                      report: Optional[dict],
                      cause: BaseException) -> Optional[np.ndarray]:
        """Quarantine ``bad`` and return the repaired index batch (None =
        nothing left).  Raises ``cause`` under the ``raise`` policy —
        after recording, so the log still names the culprit."""
        self._quarantine(bad, report)
        if self.on_bad_sample == "raise":
            raise cause
        bad_ids = np.array(sorted(bad), dtype=idx.dtype)
        if self.on_bad_sample == "skip":
            kept = idx[~np.isin(idx, bad_ids)]
            return kept if kept.size else None
        # substitute: replace in place, preserving batch size
        out = idx.copy()
        taken = set(int(i) for i in idx)
        for pos in np.flatnonzero(np.isin(idx, bad_ids)):
            sub = self._substitute_for(int(idx[pos]), taken)
            if sub is None:             # population exhausted: drop
                out[pos] = -1
                continue
            taken.add(sub)
            out[pos] = sub
        out = out[out >= 0]
        return out if out.size else None

    # ---- probing ----------------------------------------------------------
    def _probe(self, dataset, idx: np.ndarray, fast: bool,
               catch_all: bool) -> Dict[int, str]:
        """Attribute an unattributed batch failure: read items one by one
        (with quick retries) and blame the ones that still fail.  Brownout
        failures blame nobody — the storage is down, not the item."""
        bad: Dict[int, str] = {}
        for i in idx:
            one = np.array([i], dtype=idx.dtype)
            for attempt in range(1 + max(0, self.retry.attempts)):
                try:
                    dataset.get_batch(one, fast=fast)
                    break
                except BrownoutError:
                    return {}           # unattributable: escalate
                except CorruptSampleError:
                    bad[int(i)] = "corrupt"
                    break
                except (SampleReadError, IOError, OSError) as e:
                    if attempt >= self.retry.attempts:
                        bad[int(i)] = f"retries-exhausted: {e}"
                    else:
                        time.sleep(self.retry.sleep_s(attempt + 1, int(i)))
                except Exception as e:  # noqa: BLE001 - poisoned transform
                    if not catch_all:
                        raise
                    bad[int(i)] = f"poisoned: {type(e).__name__}: {e}"
                    break
        return bad

    # ---- the resilient read ------------------------------------------------
    def get_batch(self, dataset, indices, *, out=None, fast: bool = True,
                  report: Optional[dict] = None):
        """``dataset.get_batch`` with retries, quarantine and batch repair.
        Returns None when every index of the batch is quarantined (the
        pool skips the sequence slot).  ``report``, when given, collects
        the per-task tally a process-pool child ships to its parent."""
        idx = np.asarray(indices).reshape(-1)
        if len(self.quarantine):
            known = self.quarantine.ids()
            mask = np.isin(idx, known)
            if mask.any():
                if self.on_bad_sample == "substitute":
                    repaired = self._apply_policy(
                        idx, {int(i): "quarantined" for i in idx[mask]},
                        report, cause=RuntimeError("unreachable"))
                    idx = repaired if repaired is not None else idx[:0]
                else:
                    idx = idx[~mask]    # raise-mode restores skip too:
                    #                     quarantined means "do not serve"
            if idx.size == 0:
                return None
        deadline = time.monotonic() + self.retry.deadline_s
        fails: Dict[int, int] = {}      # per-ITEM failure counts: one
        #                                 flaky neighbour must not burn
        #                                 another item's retry budget
        while True:
            try:
                batch = dataset.get_batch(idx, out=out, fast=fast)
            except CorruptSampleError as e:
                self.stats.note_fault()
                if report is not None:
                    report["faults"] = report.get("faults", 0) + 1
                bad = {int(e.index): "corrupt"} if e.index is not None \
                    else self._probe(dataset, idx, fast, catch_all=False)
                if not bad:
                    raise
                idx = self._apply_policy(idx, bad, report, cause=e)
                if idx is None:
                    return None
                deadline = time.monotonic() + self.retry.deadline_s
            except (SampleReadError, IOError, OSError) as e:
                self.stats.note_fault()
                if report is not None:
                    report["faults"] = report.get("faults", 0) + 1
                index = getattr(e, "index", None)
                brownout = isinstance(e, BrownoutError)
                if index is not None and not brownout:
                    # item-attributed transient: consumes one of that
                    # item's attempts
                    fails[int(index)] = fails.get(int(index), 0) + 1
                exhausted = (index is not None and not brownout
                             and fails[int(index)]
                             > max(0, self.retry.attempts)) \
                    or time.monotonic() >= deadline
                if not exhausted:
                    retry_no = fails.get(int(index), 1) \
                        if index is not None else 1
                    self.stats.note_retry()
                    if report is not None:
                        report["retries"] = report.get("retries", 0) + 1
                    time.sleep(self.retry.sleep_s(
                        retry_no, int(index if index is not None
                                      else idx[0])))
                    continue
                if index is not None and not brownout:
                    bad = {int(index): f"retries-exhausted: {e}"}
                else:
                    bad = self._probe(dataset, idx, fast, catch_all=False)
                if not bad:
                    raise               # brownout outlasted the deadline
                idx = self._apply_policy(idx, bad, report, cause=e)
                if idx is None:
                    return None
                deadline = time.monotonic() + self.retry.deadline_s
            except Exception as e:      # noqa: BLE001 - poisoned transform
                if self.on_bad_sample == "raise":
                    raise               # legacy behavior: pool-fatal
                self.stats.note_fault()
                if report is not None:
                    report["faults"] = report.get("faults", 0) + 1
                bad = self._probe(dataset, idx, fast, catch_all=True)
                if not bad:
                    raise               # not per-item poison: a real bug
                idx = self._apply_policy(idx, bad, report, cause=e)
                if idx is None:
                    return None
            else:
                self.stats.note_ok()
                if report is not None:
                    report["ok"] = report.get("ok", 0) + 1
                return batch

    # the quarantine-callback closes over loader state; forked/pickled
    # copies report deltas back instead of calling it directly
    def __getstate__(self):
        state = self.__dict__.copy()
        state["on_quarantine"] = None
        return state


def quarantine_complement(n: int, quarantine: QuarantineLog) -> np.ndarray:
    """All ids of range(n) not quarantined — the exact multiset a
    skip-policy epoch must deliver (tests/benches assert against this)."""
    mask = np.ones(n, dtype=bool)
    ids = quarantine.ids()
    mask[ids[ids < n]] = False
    return np.flatnonzero(mask)
