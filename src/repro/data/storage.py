"""Storage backends for the data pipeline.

The loader (and therefore DPT) only sees the ``Storage`` interface, so the
same tuner runs against:

* ``ArrayStorage``   — in-memory items (unit tests, toy examples),
* ``FileStorage``    — real files on disk (.npy per item),
* ``LatencyStorage`` — wraps another storage and injects real ``time.sleep``
  IO latency + bandwidth delays (integration tests exercise real thread
  parallelism against it: sleep releases the GIL),
* ``StorageProfile`` — the *virtual-time* description used by the
  discrete-event simulator for the paper-table benchmarks (this container
  has one CPU core, so multi-core scaling curves are simulated; see
  DESIGN.md §2 "Assumptions changed").

Every backend also exposes a vectorized ``read_batch(indices)`` — the
storage half of the zero-copy fast path (DESIGN.md §3).  The default loops
``read``; real backends do better: ``ArrayStorage`` gathers the whole batch
in one fancy-index pass over a dense array, ``FileStorage`` memory-maps
items, and ``LatencyStorage`` charges one base latency per *coalesced
contiguous run* of misses instead of one per item (what a real storage
stack's readahead/scatter-gather does for batched requests).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import weakref
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class StorageProfile:
    """Virtual-time storage/dataset characteristics (simulator input).

    ``item_bytes`` is the *encoded* on-storage size (what IO and the page
    cache see); ``decoded_item_bytes`` is the in-memory decoded sample (what
    worker queues, the device transfer and decode CPU cost see).  The seek
    model io_latency(K) = io_latency_s * (1 + seek_congestion*K) is fitted
    from the paper's own COCO numbers (405s cold / 8.7s warm epochs at 80x80
    imply ~8 ms base request latency growing ~0.3x per concurrent reader —
    random small reads on consumer storage serialize at the disk).

    Fast-path coalescing fields (DESIGN.md §3): ``coalesced_run_len`` is the
    mean number of items served per storage request when the loader issues
    batched ``read_batch`` calls (1.0 = per-item requests, the legacy
    behavior — also what a fully shuffled access pattern degrades to);
    ``vectorized_decode_fixed_s`` is the amortized per-item fixed decode
    cost under the vectorized batch transform (None = per-sample
    ``decode_cpu_s_fixed``).  Defaults are neutral, so existing simulated
    grids and their optima are bit-for-bit unchanged.
    """
    num_items: int
    item_bytes: float                 # mean encoded item size
    decoded_item_bytes: Optional[float] = None
    item_bytes_std: float = 0.0
    io_latency_s: float = 100e-6      # per-request base latency
    seek_congestion: float = 0.0      # latency growth per concurrent reader
    storage_bw: float = 2.0e9         # aggregate sequential read B/s
    ram_bw: float = 10.0e9            # page-cache read B/s
    decode_cpu_s_per_byte: float = 4e-9  # decode CPU s per *decoded* byte
    decode_cpu_s_fixed: float = 150e-6   # per-item fixed CPU cost
    coalesced_run_len: float = 1.0       # items per request under read_batch
    vectorized_decode_fixed_s: Optional[float] = None
    # Heavy-tailed per-item cost (DESIGN.md §9): ``tail_fraction`` of items
    # cost ``tail_mult``x the mean decode+IO time (corrupt JPEGs, giant
    # outlier images, cold dedup segments...).  Neutral defaults keep every
    # existing simulated grid bit-for-bit identical.
    tail_fraction: float = 0.0           # fraction of items that are slow
    tail_mult: float = 1.0               # cost multiplier for those items

    @property
    def decoded(self) -> float:
        return self.decoded_item_bytes or 4.0 * self.item_bytes

    @property
    def dataset_bytes(self) -> float:
        return self.num_items * self.item_bytes

    @property
    def effective_decode_fixed_s(self) -> float:
        if self.vectorized_decode_fixed_s is None:
            return self.decode_cpu_s_fixed
        return self.vectorized_decode_fixed_s

    def with_fast_path(self, *, run_len: float = 8.0,
                       decode_fixed_s: Optional[float] = None
                       ) -> "StorageProfile":
        """This profile as seen by the batched fast path: requests coalesce
        into runs of ``run_len`` items and the per-item fixed decode cost
        amortizes to ``decode_fixed_s`` (default: 1/8 of per-sample)."""
        if decode_fixed_s is None:
            decode_fixed_s = self.decode_cpu_s_fixed / 8.0
        return dataclasses.replace(
            self, coalesced_run_len=max(1.0, run_len),
            vectorized_decode_fixed_s=decode_fixed_s)

    def with_heavy_tail(self, *, fraction: float = 0.05,
                        mult: float = 20.0) -> "StorageProfile":
        """This profile with a straggler population: ``fraction`` of items
        cost ``mult``x the per-item mean (what the slow-lane knob prices)."""
        return dataclasses.replace(
            self, tail_fraction=max(0.0, min(1.0, fraction)),
            tail_mult=max(1.0, mult))


# --------------------------------------------------------------------------
# fault vocabulary (DESIGN.md §10): storage backends raise these, the
# worker-pool retry machinery (data/faults.py) catches and classifies them
# --------------------------------------------------------------------------
class SampleReadError(IOError):
    """A read failed.  ``index`` names the culprit item when the backend
    can attribute the failure to one (None = whole-request failure)."""

    def __init__(self, message: str, *, index: Optional[int] = None):
        super().__init__(message)
        self.index = index

    # IOError's default __reduce__ drops keyword state; carry ``index``
    # across process boundaries (a child's raise ships back via pickle)
    def __reduce__(self):
        return (self.__class__, (str(self),), {"index": self.index})


class TransientReadError(SampleReadError):
    """Retryable: the same read may succeed on the next attempt."""


class BrownoutError(TransientReadError):
    """The storage itself is unavailable — never attributable to an item,
    so retry budgets treat it as deadline-bounded, not attempt-bounded,
    and nothing is ever quarantined for failing during a brownout."""


class CorruptSampleError(SampleReadError):
    """Permanent: this item will never read correctly (bad bytes on
    storage).  Retrying is pointless; the item is quarantine material."""


_SPLITMIX_M64 = (1 << 64) - 1


def splitmix_u01(seed: int, idx: int, salt: int = 0) -> float:
    """Deterministic uniform in [0, 1) from (seed, idx, salt) — a
    splitmix64-style integer mix with no RNG state to share or fork.
    Shared by the heavy-tail draw, the fault draws and jittered backoff."""
    x = (int(idx) * 0x9E3779B97F4A7C15
         + (int(seed) * 2 + int(salt) + 1) * 0xBF58476D1CE4E5B9) \
        & _SPLITMIX_M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _SPLITMIX_M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _SPLITMIX_M64
    x ^= x >> 31
    return x / float(1 << 64)


def coalesce_runs(indices: Sequence[int]) -> List[Tuple[int, int]]:
    """Group sorted(indices) into maximal contiguous runs [(start, length)].

    This is the request pattern a batched read issues: one storage request
    per run (readahead serves the rest of the run from the same seek).
    """
    if len(indices) == 0:
        return []
    idx = sorted(int(i) for i in indices)
    runs = [(idx[0], 1)]
    for i in idx[1:]:
        start, length = runs[-1]
        if i == start + length:
            runs[-1] = (start, length + 1)
        else:
            runs.append((i, 1))
    return runs


class Storage:
    """Indexable raw-item store."""

    def __len__(self) -> int:
        raise NotImplementedError

    def read(self, idx: int) -> np.ndarray:
        raise NotImplementedError

    def read_batch(self, indices) -> Union[np.ndarray, List[np.ndarray]]:
        """Vectorized gather.  May return a stacked ``(B, ...)`` array when
        items are uniform, or a list of per-item arrays.  The default loops
        ``read``; backends override with genuinely batched IO."""
        return [self.read(int(i)) for i in indices]

    def item_nbytes(self, idx: int) -> int:
        raise NotImplementedError

    def profile(self) -> StorageProfile:
        """Best-effort virtual profile (for DPT cache fingerprints)."""
        n = len(self)
        sizes = [self.item_nbytes(i) for i in range(min(n, 16))]
        return StorageProfile(num_items=n, item_bytes=float(np.mean(sizes)),
                              item_bytes_std=float(np.std(sizes)))


class ArrayStorage(Storage):
    """In-memory items.  Uniform-shape items are densified into one
    ``(N, ...)`` array at construction, so ``read_batch`` is a single
    fancy-index gather (one C call) instead of B Python reads."""

    def __init__(self, items):
        self._items = list(items)
        self._dense: Optional[np.ndarray] = None
        if self._items:
            first = np.asarray(self._items[0])
            if all(isinstance(a, np.ndarray) and a.shape == first.shape
                   and a.dtype == first.dtype for a in self._items):
                self._dense = np.stack(self._items)
                # items become views of the dense array: no duplication
                self._items = list(self._dense)

    def __len__(self):
        return len(self._items)

    def read(self, idx):
        return self._items[idx]

    def read_batch(self, indices):
        if self._dense is not None:
            return self._dense[np.asarray(indices, dtype=np.intp)]
        return [self._items[int(i)] for i in indices]

    def item_nbytes(self, idx):
        return self._items[idx].nbytes


# FileStorage instances whose mmap caches must be dropped in a forked
# child: a fork duplicates the parent's open handles/mappings into the
# child (where they are dead weight at best — the child lazily reopens on
# first use).  Pickling already drops them (__getstate__); this covers the
# fork-without-pickle path (ProcessWorkerPool's fork pool inherits the
# parent's live objects at Pool() creation).
_FORK_RESET_STORAGES: "weakref.WeakSet" = weakref.WeakSet()


def _drop_inherited_mmaps() -> None:   # runs in the CHILD, right after fork
    for fs in list(_FORK_RESET_STORAGES):
        fs._mmaps = {}
        fs._mmap_lock = threading.Lock()


if hasattr(os, "register_at_fork"):    # pragma: no branch - CPython 3.7+
    os.register_at_fork(after_in_child=_drop_inherited_mmaps)


class FileStorage(Storage):
    """One .npy file per item under ``root``.

    Per-item sizes are stat'ed once at construction (DPT's static memory
    pre-check reads them repeatedly); ``read_batch`` goes through cached
    ``np.load(mmap_mode='r')`` handles so repeat epochs hit the page cache
    without re-parsing headers.
    """

    _MAX_MMAPS = 4096   # cap cached file handles

    def __init__(self, root: str):
        self.root = root
        self._files = sorted(
            f for f in os.listdir(root) if f.endswith(".npy"))
        self._paths = [os.path.join(root, f) for f in self._files]
        self._sizes = [os.path.getsize(p) for p in self._paths]
        self._mmaps: dict = {}
        self._mmap_lock = threading.Lock()
        _FORK_RESET_STORAGES.add(self)

    @classmethod
    def create(cls, root: str, items) -> "FileStorage":
        os.makedirs(root, exist_ok=True)
        for i, arr in enumerate(items):
            np.save(os.path.join(root, f"{i:08d}.npy"), arr)
        return cls(root)

    def __len__(self):
        return len(self._files)

    def read(self, idx):
        return np.load(self._paths[idx])

    def _mmap(self, idx: int) -> np.ndarray:
        with self._mmap_lock:
            m = self._mmaps.get(idx)
            if m is None:
                if len(self._mmaps) >= self._MAX_MMAPS:
                    self._mmaps.clear()
                m = self._mmaps[idx] = np.load(self._paths[idx],
                                               mmap_mode="r")
            return m

    def read_batch(self, indices):
        return [np.asarray(self._mmap(int(i))) for i in indices]

    def item_nbytes(self, idx):
        return self._sizes[idx]

    # mmap handles and their lock don't cross process boundaries — a forked
    # ProcessWorkerPool pickles the dataset per task (see _mp_get_batch)
    def __getstate__(self):
        state = self.__dict__.copy()
        state["_mmaps"] = {}
        state["_mmap_lock"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._mmap_lock = threading.Lock()
        _FORK_RESET_STORAGES.add(self)


class LatencyStorage(Storage):
    """Wraps a storage and injects real sleep-based IO latency/bandwidth.

    Sleeping releases the GIL, so a thread worker pool sees true concurrency
    gains — this is how the loader's parallel machinery is exercised for
    real on a 1-core container.  An optional page cache makes repeat reads
    cheap (the paper's 1st-vs-2nd-epoch effect).

    ``read_batch`` models what a batched request actually costs: cache
    misses are sorted and coalesced into contiguous runs, each run pays ONE
    base latency plus its total bytes over the bandwidth (``coalesce_runs``)
    — a fully contiguous batch of B items costs 1 seek instead of B.
    Counters: ``reads``/``cache_hits`` are per item, ``batched_reads`` per
    ``read_batch`` call, ``coalesced_requests`` per run actually issued.

    Heavy-tailed cost mode (DESIGN.md §9): with ``tail_fraction > 0`` a
    seeded, *deterministic* subset of items costs extra on every miss —
    ``tail_mode="bimodal"`` charges tail items ``(tail_mult - 1)`` extra
    base latencies (a clean two-population straggler workload, the bench /
    property-test shape), ``"lognormal"`` draws a per-item multiplier from
    a seeded lognormal with median 1 (a smoother real-decode shape).  The
    draw is a pure hash of ``(tail_seed, idx)``: no RNG state, identical
    across threads, processes and epochs — stragglers are reproducible
    without wall-clock-dominating sleeps (tail cost scales with
    ``latency_s``, so CI keeps it tiny).

    Fault mode (DESIGN.md §10): with ``fault_rate > 0`` a cache *miss*
    raises :class:`TransientReadError` with probability ``fault_rate``,
    drawn from ``splitmix_u01(fault_seed, idx, attempt)`` — the draw is
    re-keyed by the item's failure count, so an item that faulted once is
    not doomed to fault forever: retries deterministically clear.
    ``brownout=(start, stop)`` fails EVERY miss while the storage's access
    clock (one tick per ``read``/``read_batch`` call) is inside the
    window, raising :class:`BrownoutError`; retries advance the clock, so
    a brownout heals under sustained traffic.  Cache hits are always
    served — the "serve-hits-first" half of degraded mode is a property of
    the storage, not just the loader.
    """

    def __init__(self, inner: Storage, *, latency_s: float = 1e-3,
                 bandwidth: float = 1e9, cache_bytes: int = 0,
                 concurrent_streams: int = 8, tail_fraction: float = 0.0,
                 tail_mult: float = 1.0, tail_seed: int = 0,
                 tail_mode: str = "bimodal", fault_rate: float = 0.0,
                 fault_seed: int = 0,
                 brownout: Optional[Tuple[int, int]] = None):
        if tail_mode not in ("bimodal", "lognormal"):
            raise ValueError(f"unknown tail_mode: {tail_mode!r}")
        self.inner = inner
        self.latency_s = latency_s
        self.bandwidth = bandwidth
        self.cache_bytes = cache_bytes
        self.tail_fraction = max(0.0, min(1.0, tail_fraction))
        self.tail_mult = max(1.0, tail_mult)
        self.tail_seed = int(tail_seed)
        self.tail_mode = tail_mode
        self.fault_rate = max(0.0, min(1.0, fault_rate))
        self.fault_seed = int(fault_seed)
        self.brownout = tuple(brownout) if brownout else None
        self._cache: dict = {}
        self._cache_used = 0
        self._lock = threading.Lock()
        self._sem = threading.Semaphore(concurrent_streams)
        self.reads = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.batched_reads = 0
        self.coalesced_requests = 0
        self.faults_injected = 0
        self._access_clock = 0          # read/read_batch calls so far
        self._fault_attempts: Dict[int, int] = {}   # idx -> failures so far

    def __len__(self):
        return len(self.inner)

    def item_nbytes(self, idx):
        return self.inner.item_nbytes(idx)

    # ---- heavy tail --------------------------------------------------------
    def _item_u01(self, idx: int, salt: int = 0) -> float:
        """Deterministic uniform in [0, 1) from (tail_seed, idx, salt)."""
        return splitmix_u01(self.tail_seed, idx, salt)

    def tail_multiplier(self, idx: int) -> float:
        """Per-item miss-cost multiplier (1.0 when the tail is off)."""
        if self.tail_fraction <= 0.0 or self.tail_mult <= 1.0:
            return 1.0
        if self.tail_mode == "bimodal":
            tail = self._item_u01(idx) < self.tail_fraction
            return self.tail_mult if tail else 1.0
        # lognormal: median-1 multiplier whose ~p98 reaches tail_mult
        import math
        u1 = max(self._item_u01(idx), 1e-12)
        u2 = self._item_u01(idx, salt=1)
        z = math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)
        sigma = math.log(self.tail_mult) / 2.0
        return math.exp(sigma * z)

    def is_tail(self, idx: int) -> bool:
        """Is this item in the slow population?  (Tests/benches use this to
        plant known stragglers and check the tracker finds them.)"""
        return self.tail_multiplier(idx) >= max(2.0, self.tail_mult / 2.0)

    def _tail_extra_s(self, indices) -> float:
        """Extra sleep the tail charges for these miss items: each pays
        ``(multiplier - 1)`` additional base latencies."""
        if self.tail_fraction <= 0.0 or self.tail_mult <= 1.0:
            return 0.0
        return self.latency_s * sum(
            max(0.0, self.tail_multiplier(i) - 1.0) for i in indices)

    # ---- fault injection (DESIGN.md §10) -----------------------------------
    def _maybe_fault(self, misses, clock: int) -> None:
        """Raise for faulting misses: a brownout window fails the whole
        request (unattributable), a transient draw fails one item — keyed
        by that item's failure count, so retries clear deterministically."""
        if not misses:
            return                      # hits are always served
        if self.brownout is not None \
                and self.brownout[0] <= clock - 1 < self.brownout[1]:
            with self._lock:
                self.faults_injected += 1
            raise BrownoutError(
                f"storage brownout (access {clock} in "
                f"window {self.brownout})")
        if self.fault_rate <= 0.0:
            return
        for i in misses:
            with self._lock:
                attempt = self._fault_attempts.get(i, 0)
            # salt 101+attempt keeps the fault stream disjoint from the
            # tail draws (salts 0/1) even when the seeds coincide
            if splitmix_u01(self.fault_seed, i,
                            101 + attempt) < self.fault_rate:
                with self._lock:
                    self._fault_attempts[i] = attempt + 1
                    self.faults_injected += 1
                raise TransientReadError(
                    f"transient read fault on item {i} "
                    f"(attempt {attempt})", index=int(i))

    def _maybe_cache(self, idx: int, nbytes: int, data) -> None:
        if self.cache_bytes:
            with self._lock:
                if (idx not in self._cache
                        and self._cache_used + nbytes <= self.cache_bytes):
                    self._cache[idx] = data
                    self._cache_used += nbytes

    def read(self, idx):
        with self._lock:
            self._access_clock += 1
            clock = self._access_clock
            self.reads += 1
            cached = idx in self._cache
            if cached:
                self.cache_hits += 1
            else:
                self.cache_misses += 1
        if cached:
            return self._cache[idx]
        self._maybe_fault((idx,), clock)
        nbytes = self.inner.item_nbytes(idx)
        with self._sem:  # bounded concurrent streams share the bus
            time.sleep(self.latency_s + nbytes / self.bandwidth
                       + self._tail_extra_s((idx,)))
        data = self.inner.read(idx)
        self._maybe_cache(idx, nbytes, data)
        return data

    def read_batch(self, indices):
        indices = [int(i) for i in indices]
        with self._lock:
            self._access_clock += 1
            clock = self._access_clock
            self.reads += len(indices)
            self.batched_reads += 1
            hits = {i for i in indices if i in self._cache}
            self.cache_hits += len(hits)
            self.cache_misses += len(indices) - len(hits)
        misses = [i for i in indices if i not in hits]
        self._maybe_fault(misses, clock)
        runs = coalesce_runs(misses)
        for start, length in runs:
            run_bytes = sum(self.inner.item_nbytes(start + k)
                            for k in range(length))
            run_items = range(start, start + length)
            with self._sem:  # one request per coalesced run
                time.sleep(self.latency_s + run_bytes / self.bandwidth
                           + self._tail_extra_s(run_items))
        with self._lock:
            self.coalesced_requests += len(runs)
        miss_data = {}
        if misses:
            fetched = self.inner.read_batch(misses)
            for i, data in zip(misses, fetched):
                miss_data[i] = data
                self._maybe_cache(i, self.inner.item_nbytes(i), data)
        return [self._cache[i] if i in hits else miss_data[i]
                for i in indices]

    @property
    def achieved_run_len(self) -> float:
        """Mean cache-miss items served per storage request so far — the
        measured counterpart of ``StorageProfile.coalesced_run_len``."""
        if not self.coalesced_requests:
            return 0.0
        return (self.reads - self.cache_hits) / self.coalesced_requests


_IO_COUNTER_FIELDS = ("reads", "cache_hits", "cache_misses",
                      "batched_reads", "coalesced_requests")


def storage_io_counters(storage) -> Optional[Dict[str, float]]:
    """Snapshot of a storage's IO-efficiency counters (None when the
    backend doesn't keep them).  Duck-typed so instrumented backends other
    than ``LatencyStorage`` surface the same numbers; loaders diff two
    snapshots to attribute requests to one measurement window."""
    if not all(hasattr(storage, f) for f in _IO_COUNTER_FIELDS):
        return None
    return {f: float(getattr(storage, f)) for f in _IO_COUNTER_FIELDS}


# --- canonical dataset profiles used by the paper-table benchmarks --------
def cifar10_profile() -> StorageProfile:
    """~60K 32x32x3 images (CIFAR-10): tiny raw items, batched binary files
    (fast IO), decode = tensorize + normalize.  Fits RAM trivially, so the
    paper's CIFAR grid is the warm/CPU-bound regime."""
    return StorageProfile(num_items=60_000, item_bytes=32 * 32 * 3,
                          decoded_item_bytes=4.0 * 32 * 32 * 3,
                          io_latency_s=2e-3, seek_congestion=0.1,
                          storage_bw=200e6,
                          decode_cpu_s_fixed=120e-6,
                          decode_cpu_s_per_byte=10e-9)


def coco_profile(resolution: int) -> StorageProfile:
    """COCO-2017-unlabeled resized to resolution^2 (paper §4.3): JPEG-ish
    encoded items (~0.35 compression), fp32 decoded tensors, seek-bound
    consumer storage (constants back-fitted from paper Table 1b — see
    StorageProfile docstring)."""
    raw = resolution * resolution * 3
    enc = 0.35 * raw
    return StorageProfile(num_items=123_000, item_bytes=float(enc),
                          decoded_item_bytes=4.0 * raw,
                          item_bytes_std=0.15 * enc,
                          io_latency_s=8e-3, seek_congestion=0.31,
                          storage_bw=60e6,
                          decode_cpu_s_fixed=150e-6,
                          decode_cpu_s_per_byte=4e-9)
