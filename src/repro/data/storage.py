"""Storage backends for the data pipeline.

The loader (and therefore DPT) only sees the ``Storage`` interface, so the
same tuner runs against:

* ``ArrayStorage``   — in-memory items (unit tests, toy examples),
* ``FileStorage``    — real files on disk (.npy per item),
* ``LatencyStorage`` — wraps another storage and injects real ``time.sleep``
  IO latency + bandwidth delays (integration tests exercise real thread
  parallelism against it: sleep releases the GIL),
* ``StorageProfile`` — the *virtual-time* description used by the
  discrete-event simulator for the paper-table benchmarks (this container
  has one CPU core, so multi-core scaling curves are simulated; see
  DESIGN.md §2 "Assumptions changed").
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class StorageProfile:
    """Virtual-time storage/dataset characteristics (simulator input).

    ``item_bytes`` is the *encoded* on-storage size (what IO and the page
    cache see); ``decoded_item_bytes`` is the in-memory decoded sample (what
    worker queues, the device transfer and decode CPU cost see).  The seek
    model io_latency(K) = io_latency_s * (1 + seek_congestion*K) is fitted
    from the paper's own COCO numbers (405s cold / 8.7s warm epochs at 80x80
    imply ~8 ms base request latency growing ~0.3x per concurrent reader —
    random small reads on consumer storage serialize at the disk).
    """
    num_items: int
    item_bytes: float                 # mean encoded item size
    decoded_item_bytes: Optional[float] = None
    item_bytes_std: float = 0.0
    io_latency_s: float = 100e-6      # per-request base latency
    seek_congestion: float = 0.0      # latency growth per concurrent reader
    storage_bw: float = 2.0e9         # aggregate sequential read B/s
    ram_bw: float = 10.0e9            # page-cache read B/s
    decode_cpu_s_per_byte: float = 4e-9  # decode CPU s per *decoded* byte
    decode_cpu_s_fixed: float = 150e-6   # per-item fixed CPU cost

    @property
    def decoded(self) -> float:
        return self.decoded_item_bytes or 4.0 * self.item_bytes

    @property
    def dataset_bytes(self) -> float:
        return self.num_items * self.item_bytes


class Storage:
    """Indexable raw-item store."""

    def __len__(self) -> int:
        raise NotImplementedError

    def read(self, idx: int) -> np.ndarray:
        raise NotImplementedError

    def item_nbytes(self, idx: int) -> int:
        raise NotImplementedError

    def profile(self) -> StorageProfile:
        """Best-effort virtual profile (for DPT cache fingerprints)."""
        n = len(self)
        sizes = [self.item_nbytes(i) for i in range(min(n, 16))]
        return StorageProfile(num_items=n, item_bytes=float(np.mean(sizes)),
                              item_bytes_std=float(np.std(sizes)))


class ArrayStorage(Storage):
    def __init__(self, items):
        self._items = list(items)

    def __len__(self):
        return len(self._items)

    def read(self, idx):
        return self._items[idx]

    def item_nbytes(self, idx):
        return self._items[idx].nbytes


class FileStorage(Storage):
    """One .npy file per item under ``root``."""

    def __init__(self, root: str):
        self.root = root
        self._files = sorted(
            f for f in os.listdir(root) if f.endswith(".npy"))

    @classmethod
    def create(cls, root: str, items) -> "FileStorage":
        os.makedirs(root, exist_ok=True)
        for i, arr in enumerate(items):
            np.save(os.path.join(root, f"{i:08d}.npy"), arr)
        return cls(root)

    def __len__(self):
        return len(self._files)

    def read(self, idx):
        return np.load(os.path.join(self.root, self._files[idx]))

    def item_nbytes(self, idx):
        return os.path.getsize(os.path.join(self.root, self._files[idx]))


class LatencyStorage(Storage):
    """Wraps a storage and injects real sleep-based IO latency/bandwidth.

    Sleeping releases the GIL, so a thread worker pool sees true concurrency
    gains — this is how the loader's parallel machinery is exercised for
    real on a 1-core container.  An optional page cache makes repeat reads
    cheap (the paper's 1st-vs-2nd-epoch effect).
    """

    def __init__(self, inner: Storage, *, latency_s: float = 1e-3,
                 bandwidth: float = 1e9, cache_bytes: int = 0,
                 concurrent_streams: int = 8):
        self.inner = inner
        self.latency_s = latency_s
        self.bandwidth = bandwidth
        self.cache_bytes = cache_bytes
        self._cache: dict = {}
        self._cache_used = 0
        self._lock = threading.Lock()
        self._sem = threading.Semaphore(concurrent_streams)
        self.reads = 0
        self.cache_hits = 0

    def __len__(self):
        return len(self.inner)

    def item_nbytes(self, idx):
        return self.inner.item_nbytes(idx)

    def read(self, idx):
        with self._lock:
            self.reads += 1
            cached = idx in self._cache
            if cached:
                self.cache_hits += 1
        if cached:
            return self._cache[idx]
        nbytes = self.inner.item_nbytes(idx)
        with self._sem:  # bounded concurrent streams share the bus
            time.sleep(self.latency_s + nbytes / self.bandwidth)
        data = self.inner.read(idx)
        if self.cache_bytes:
            with self._lock:
                if self._cache_used + nbytes <= self.cache_bytes:
                    self._cache[idx] = data
                    self._cache_used += nbytes
        return data


# --- canonical dataset profiles used by the paper-table benchmarks --------
def cifar10_profile() -> StorageProfile:
    """~60K 32x32x3 images (CIFAR-10): tiny raw items, batched binary files
    (fast IO), decode = tensorize + normalize.  Fits RAM trivially, so the
    paper's CIFAR grid is the warm/CPU-bound regime."""
    return StorageProfile(num_items=60_000, item_bytes=32 * 32 * 3,
                          decoded_item_bytes=4.0 * 32 * 32 * 3,
                          io_latency_s=2e-3, seek_congestion=0.1,
                          storage_bw=200e6,
                          decode_cpu_s_fixed=120e-6,
                          decode_cpu_s_per_byte=10e-9)


def coco_profile(resolution: int) -> StorageProfile:
    """COCO-2017-unlabeled resized to resolution^2 (paper §4.3): JPEG-ish
    encoded items (~0.35 compression), fp32 decoded tensors, seek-bound
    consumer storage (constants back-fitted from paper Table 1b — see
    StorageProfile docstring)."""
    raw = resolution * resolution * 3
    enc = 0.35 * raw
    return StorageProfile(num_items=123_000, item_bytes=float(enc),
                          decoded_item_bytes=4.0 * raw,
                          item_bytes_std=0.15 * enc,
                          io_latency_s=8e-3, seek_congestion=0.31,
                          storage_bw=60e6,
                          decode_cpu_s_fixed=150e-6,
                          decode_cpu_s_per_byte=4e-9)
