"""Device prefetcher: overlaps host->device transfer with consumption.

The TPU analogue of the paper's pinned-memory + ``.cuda()`` copy: batches
are ``jax.device_put`` onto the global ``NamedSharding`` (each host provides
its local shard) ``depth`` steps ahead of the training loop, so the HBM DMA
runs concurrently with the previous step's compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

_SENTINEL = object()


def put_global_batch(batch, sharding=None):
    """Host batch (numpy dict) -> device array(s).

    With a NamedSharding whose mesh spans multiple processes, each host
    contributes its local shard via ``make_array_from_process_local_data``;
    single-process meshes (and sharding=None) fall back to device_put.
    """
    if sharding is None:
        return jax.device_put(batch)

    def _put(x):
        x = np.asarray(x)
        if jax.process_count() > 1:  # pragma: no cover - multi-host only
            return jax.make_array_from_process_local_data(sharding, x)
        return jax.device_put(x, sharding)

    return jax.tree_util.tree_map(_put, batch)


class DevicePrefetcher:
    def __init__(self, host_iter: Iterator, *, depth: int = 2, sharding=None):
        self.depth = max(1, depth)
        self.sharding = sharding
        self._queue: queue.Queue = queue.Queue(maxsize=self.depth)
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, args=(host_iter,),
                                        daemon=True)
        self._thread.start()

    def _run(self, host_iter):
        try:
            for batch in host_iter:
                self._queue.put(put_global_batch(batch, self.sharding))
        except BaseException as e:  # noqa: BLE001
            self._error = e
        finally:
            self._queue.put(_SENTINEL)

    def __iter__(self):
        while True:
            item = self._queue.get()
            if item is _SENTINEL:
                if self._error is not None:
                    raise self._error
                return
            yield item
