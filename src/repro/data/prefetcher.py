"""Device prefetcher: overlaps host->device transfer with consumption.

The TPU analogue of the paper's pinned-memory + ``.cuda()`` copy: batches
are ``jax.device_put`` onto the global ``NamedSharding`` (each host provides
its local shard) ``depth`` steps ahead of the training loop, so the HBM DMA
runs concurrently with the previous step's compute.

Fast-path extensions (DESIGN.md §3):

* ``donate=True`` passes ``jax.device_put(..., donate=True)`` so
  device-resident inputs hand their buffers to the result instead of
  copying (host numpy inputs are copied regardless — donation matters when
  an upstream stage already produced ``jax.Array``s, e.g. re-sharding);
* ``transfer_threads=2`` overlaps two host->HBM copies: a submitter thread
  feeds a tiny executor in batch order and queues the futures, so delivery
  order is preserved while transfers for consecutive batches run
  concurrently with each other and with compute;
* arena-backed batches (``ArenaBatch``) are ``detach``ed before an async
  transfer and released the moment their device copy completes, returning
  the slab to the ring as early as possible;
* a ``StagingPool`` (``staging_buffers > 0``, the default) interposes a
  small ring of preallocated host staging buffers on the device edge: the
  slab is copied into a pooled buffer once and released *immediately*
  (before the device copy even starts), and the device put runs from the
  pooled buffer with no ``may_alias=False`` / verify-and-re-put dance —
  a buffer the backend zero-copied is retired from the ring instead of
  reused, so privacy holds by construction (DESIGN.md §5).
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.data.arena import ArenaBatch

_SENTINEL = object()


def _leaf_aliases(dev, host: np.ndarray) -> bool:
    """Does device array ``dev`` share its buffer with host array ``host``?
    Only answerable (and only possible) on the CPU backend; anything that
    can't report a buffer pointer genuinely copied."""
    try:
        return dev.unsafe_buffer_pointer() == \
            host.__array_interface__["data"][0]
    except Exception:  # pragma: no cover - non-CPU / sharded arrays
        return False


def put_global_batch(batch, sharding=None, *, donate: bool = False,
                     may_alias=None):
    """Host batch (numpy dict) -> device array(s).

    With a NamedSharding whose mesh spans multiple processes, each host
    contributes its local shard via ``make_array_from_process_local_data``;
    single-process meshes (and sharding=None) fall back to device_put.

    ``may_alias=False`` forces a real copy: on the CPU backend device_put
    zero-copies numpy buffers when it can, which is exactly wrong for a
    recycled arena slab (the "device" array would mutate when the slab is
    reused) — the prefetcher passes False for arena-backed batches.
    """
    if sharding is None:
        try:
            return jax.device_put(batch, donate=donate, may_alias=may_alias)
        except TypeError:  # pragma: no cover - older jax signature
            return jax.device_put(batch)

    def _put(x):
        x = np.asarray(x)
        if jax.process_count() > 1:  # pragma: no cover - multi-host only
            return jax.make_array_from_process_local_data(sharding, x)
        try:
            return jax.device_put(x, sharding, donate=donate,
                                  may_alias=may_alias)
        except TypeError:  # pragma: no cover - older jax signature
            return jax.device_put(x, sharding)

    return jax.tree_util.tree_map(_put, batch)


class StagingPool:
    """Pinned staging-buffer ring for the device edge (DESIGN.md §5).

    The zero-copy pipeline's last host hop: an arena slab must not be
    recycled while a device copy might still read (or alias) it.  PR 2
    solved that with ``may_alias=False`` + a per-batch verify-and-re-put
    (``_ensure_private`` — jax 0.4.37's concurrent ``device_put`` can
    ignore ``may_alias=False``).  The pool replaces the dance: the slab is
    copied ONCE into a pooled buffer shaped like the device batch and
    released on the spot, and the device put runs from the pooled buffer.
    A buffer the backend genuinely copied returns to the ring (hit on next
    acquire); one the backend zero-copied now *backs a live device array*
    and is retired instead — it is never written again, so the device
    array can never be mutated by recycling.

    The spec (field shapes/dtypes) latches from the first batch; a batch
    of a different shape (reshard, ragged makeup chunk) drops the stale
    ring and re-establishes it.  ``hit_rate``/``retired`` feed
    ``TransferStats.staging_hit_rate`` and the monitor report.
    """

    def __init__(self, capacity: int):
        self.capacity = max(1, capacity)
        self._spec: Optional[Dict[str, tuple]] = None
        self._free: deque = deque()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.retired = 0

    def acquire(self, batch: Dict) -> Dict[str, np.ndarray]:
        """A staging dict matching ``batch``'s field spec.  Never blocks
        and never fails: a miss allocates (transfers already in flight
        bound how many buffers can be out; ``release`` drops surplus)."""
        spec = {k: (np.asarray(v).shape, np.asarray(v).dtype)
                for k, v in batch.items()}
        with self._lock:
            if self._spec != spec:
                if self._ragged_of(spec, self._spec):
                    # a short batch (skip-mode quarantine, makeup tail):
                    # transient — allocate fresh without thrashing the
                    # ring the full-size batches still need
                    self.misses += 1
                    return {k: np.empty(shape, dtype)
                            for k, (shape, dtype) in spec.items()}
                # first batch, or the batch shape changed (reshard):
                # pooled buffers of the old shape are useless — drop them
                self._free.clear()
                self._spec = spec
            if self._free:
                self.hits += 1
                return self._free.popleft()
            self.misses += 1
        return {k: np.empty(shape, dtype) for k, (shape, dtype) in
                spec.items()}

    @staticmethod
    def _ragged_of(spec, latched) -> bool:
        """Is ``spec`` the latched spec with a smaller leading dim (same
        fields, dtypes, trailing dims)?"""
        if latched is None or set(spec) != set(latched):
            return False
        for k, (shape, dtype) in spec.items():
            lshape, ldtype = latched[k]
            if (dtype != ldtype or len(shape) != len(lshape)
                    or not shape or shape[0] >= lshape[0]
                    or shape[1:] != lshape[1:]):
                return False
        return True

    def release(self, buf: Dict[str, np.ndarray]) -> None:
        """The device copy landed in a private buffer: back to the ring
        (dropped if the spec moved on or the ring is full)."""
        with self._lock:
            spec = {k: (v.shape, v.dtype) for k, v in buf.items()}
            if spec == self._spec and len(self._free) < self.capacity:
                self._free.append(buf)

    def retire(self, buf: Dict[str, np.ndarray]) -> None:
        """The device array aliases this buffer — it belongs to the device
        array now and must never be reused."""
        with self._lock:
            self.retired += 1

    def resize(self, capacity: int) -> None:
        with self._lock:
            self.capacity = max(1, capacity)
            while len(self._free) > self.capacity:
                self._free.pop()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class _DepthGate:
    """Resizable in-flight bound (the hot-swappable ``device_prefetch``).

    A plain ``queue.Queue(maxsize=depth)`` fixes the depth at construction;
    this gate moves the bound into a permit counter so ``set_depth`` can
    grow it (release extra permits) or shrink it (absorb permits as the
    consumer returns them) on a LIVE prefetcher without blocking either
    side — which is what lets ``apply_params`` retune the device buffer
    depth mid-stream instead of only at stream creation.
    """

    def __init__(self, depth: int):
        self.depth = max(1, depth)
        self._sem = threading.Semaphore(self.depth)
        self._lock = threading.Lock()
        self._deficit = 0            # permits to absorb after a shrink

    def acquire(self, stop: threading.Event) -> bool:
        """Producer side: take a permit (False when stopped while waiting)."""
        while not stop.is_set():
            if self._sem.acquire(timeout=0.05):
                return True
        return False

    def release(self) -> None:
        """Consumer side: return a permit (absorbed if the depth shrank)."""
        with self._lock:
            if self._deficit > 0:
                self._deficit -= 1
                return
        self._sem.release()

    def set_depth(self, depth: int) -> None:
        depth = max(1, depth)
        with self._lock:
            delta = depth - self.depth
            self.depth = depth
            if delta > 0:
                absorb = min(self._deficit, delta)
                self._deficit -= absorb
                for _ in range(delta - absorb):
                    self._sem.release()
            elif delta < 0:
                self._deficit += -delta


class DevicePrefetcher:
    def __init__(self, host_iter: Iterator, *, depth: int = 2, sharding=None,
                 transfer_threads: int = 1, donate: bool = False,
                 staging_buffers: int = 2):
        self.sharding = sharding
        self.donate = donate
        self.transfer_threads = max(1, transfer_threads)
        self._staging = (StagingPool(staging_buffers)
                         if staging_buffers > 0 else None)
        self._gate = _DepthGate(depth)
        self._queue: queue.Queue = queue.Queue()   # bounded by the gate
        self._error: Optional[BaseException] = None
        self._stop = threading.Event()
        self._executor = (ThreadPoolExecutor(
            max_workers=self.transfer_threads,
            thread_name_prefix="device-transfer")
            if self.transfer_threads > 1 else None)
        self._thread = threading.Thread(target=self._run, args=(host_iter,),
                                        daemon=True)
        self._thread.start()

    @property
    def depth(self) -> int:
        return self._gate.depth

    def set_depth(self, depth: int) -> None:
        """Retune the prefetch depth on the live stream (hot swap)."""
        self._gate.set_depth(depth)

    def set_staging(self, staging_buffers: int) -> None:
        """Retune (or disable) the staging ring on the live stream.  Runs
        at the same params boundary as ``set_depth``; in-flight transfers
        finish against the pool they started with."""
        if staging_buffers <= 0:
            self._staging = None
        elif self._staging is None:
            self._staging = StagingPool(staging_buffers)
        else:
            self._staging.resize(staging_buffers)

    @property
    def staging_hit_rate(self) -> Optional[float]:
        """Staging-pool hit rate (None when the pool is disabled)."""
        return self._staging.hit_rate if self._staging is not None else None

    def close(self) -> None:
        """Stop prefetching and unblock the producer thread (which may be
        parked on the depth gate).  Safe to call more than once."""
        self._stop.set()
        while self._thread.is_alive():
            try:
                self._queue.get_nowait()
            except queue.Empty:
                self._thread.join(timeout=0.05)

    def _transfer(self, batch):
        # ArenaBatch is a dict subclass, which jax's pytree registry treats
        # as a leaf — hand device_put a plain dict over the same arrays, and
        # forbid buffer aliasing so the recycled slab can't mutate the
        # transferred array (CPU backend zero-copies plain numpy otherwise)
        arena_backed = isinstance(batch, ArenaBatch)
        payload = dict(batch) if arena_backed else batch
        # snapshot the pool: set_staging(0) may null self._staging while a
        # transfer is in flight — it must finish against the pool it
        # started with
        staging = self._staging
        if arena_backed and staging is not None:
            try:
                staged = staging.acquire(payload)
            except BaseException:
                batch.release()    # allocation failed: never strand a slot
                raise
            return self._transfer_staged(batch, staged, staging)
        try:
            dev = put_global_batch(payload, self.sharding, donate=self.donate,
                                   may_alias=False if arena_backed else None)
            if arena_backed:
                # device_put is asynchronous: the host->device copy may
                # still be reading the slab.  Block (in this transfer
                # thread, not the consumer) until the copy lands.
                jax.block_until_ready(dev)
                dev = self._ensure_private(dev, payload)
            return dev
        finally:
            if arena_backed:
                batch.release()    # even on a failed transfer: never leak

    def _transfer_staged(self, batch: ArenaBatch, staged, pool: StagingPool):
        """Staging fast path: one host memcpy frees the slab immediately;
        the device put runs from the pooled buffer, whose privacy is
        settled once (alias -> retire) instead of verified-and-re-put per
        batch."""
        try:
            batch.copy_into(staged)
        finally:
            batch.release()        # slab is free the moment the copy ends
        try:
            dev = put_global_batch(staged, self.sharding, donate=self.donate)
            # the (async) put may still be reading the staging buffer — and
            # on a zero-copying backend the result may *be* the buffer
            jax.block_until_ready(dev)
        except BaseException:
            pool.release(staged)   # unused after a failed put
            raise
        if any(_leaf_aliases(d, staged[k]) for k, d in dev.items()):
            pool.retire(staged)    # owned by the device array now
        else:
            pool.release(staged)
        return dev

    def _ensure_private(self, dev, host):
        """Guarantee no transferred leaf still aliases its source slab.

        Observed on jax 0.4.37 (CPU backend): concurrent ``device_put``
        dispatches can ignore ``may_alias=False`` and return a zero-copy
        view of the input — fatal for a slab that is about to be recycled.
        Leaves that did get private buffers pass through untouched; an
        aliased leaf is re-put from an explicit host copy (which jax may
        alias freely: nothing ever mutates it).
        """
        fixed = {}
        for k, d in dev.items():
            h = np.asarray(host[k])
            if _leaf_aliases(d, h):
                d = put_global_batch(np.array(h), self.sharding,
                                     donate=self.donate)
            fixed[k] = d
        return fixed

    def _run(self, host_iter):
        try:
            for batch in host_iter:
                if self._stop.is_set():
                    break
                # take ownership *before* advancing host_iter (the pool
                # would otherwise recycle the slab under an in-flight copy)
                if isinstance(batch, ArenaBatch):
                    batch.detach()
                if not self._gate.acquire(self._stop):
                    # closed while waiting for a free depth slot: the batch
                    # never transfers — recycle it rather than leak
                    if isinstance(batch, ArenaBatch):
                        batch.release()
                    break
                if self._executor is None:
                    # synchronous put: the slab is free once _transfer
                    # returns, before the pool's auto-release even runs
                    self._queue.put(self._transfer(batch))
                else:
                    self._queue.put(self._executor.submit(
                        self._transfer, batch))
        except BaseException as e:  # noqa: BLE001
            self._error = e
        finally:
            if self._executor is not None:
                self._executor.shutdown(wait=True)
            self._queue.put(_SENTINEL)

    def __iter__(self):
        while True:
            item = self._queue.get()
            if item is _SENTINEL:
                if self._error is not None:
                    raise self._error
                return
            self._gate.release()
            if isinstance(item, Future):
                item = item.result()
            yield item
