"""Per-sample cost tracking: the signal behind the dual-lane slow path.

Heavy-tailed preprocessing is the one failure mode every tuned
configuration shares: with ``ordered=True`` the reorder window parks every
fast batch behind a single slow decode, so goodput collapses regardless of
(workers, prefetch, locality, cache).  The fix (DESIGN.md §9) needs a
*prediction*: which items will be slow next time?  This module provides it.

``SampleCostTracker`` keeps an EWMA of per-item decode/IO wall time, fed by
the worker pools (one ``record(indices, seconds)`` per collated batch) and
read at dispatch time (``is_slow(indices)``) to route predicted-slow
batches to the slow lane.  Batches only measure an aggregate, so the
recorded time is attributed *proportionally to current predictions*
(EM-style): a known-slow item absorbs the batch's excess instead of
smearing it over its fast neighbours — after a couple of epochs the
per-item estimates separate cleanly even though no per-item timer ever ran.

Buckets: per-item by default; datasets beyond ``max_slots`` items fall
back to chunk-id buckets (``idx // bucket``) so the table stays a few
hundred KB regardless of dataset size.  The whole tracker is plain numpy +
scalars: picklable, checkpointable (``state_dict``/``load_state_dict``),
and cheap enough to update on the hot path.

``KeyedCostTracker`` is the serving-side analogue: an EWMA per hashable
request key (e.g. ``(prompt_len, max_new_tokens)``) used by the
``BatchingFrontend`` to segregate expensive request groups so cheap
requests keep their p99 (DESIGN.md §9).
"""
from __future__ import annotations

import threading
from typing import Dict, Hashable, List, Optional, Sequence

import numpy as np


class SampleCostTracker:
    """EWMA per-item (or per-bucket) preprocessing cost, with a slow test.

    ``threshold``: an item is predicted slow when its estimated cost is at
    least ``threshold`` times the median estimated cost of everything
    observed so far; a batch is slow when any member is.  Until
    ``min_records`` batches were recorded nothing is ever called slow —
    a cold tracker must not route traffic on noise.
    """

    def __init__(self, num_items: int, *, bucket: Optional[int] = None,
                 alpha: float = 0.3, alpha_down: float = 0.8,
                 threshold: float = 4.0, outlier_mult: float = 2.0,
                 min_records: int = 8, max_slots: int = 1 << 16):
        self.num_items = max(1, int(num_items))
        if bucket is None:
            bucket = max(1, -(-self.num_items // max_slots))
        self.bucket = max(1, int(bucket))
        self.alpha = float(alpha)
        self.alpha_down = float(alpha_down)
        self.threshold = float(threshold)
        self.outlier_mult = float(outlier_mult)
        self.min_records = int(min_records)
        n_slots = -(-self.num_items // self.bucket)
        self._ewma = np.full(n_slots, np.nan, dtype=np.float64)
        self._lock = threading.Lock()
        self._mean = 0.0              # running EWMA of per-item cost
        self._median = 0.0            # cached; refreshed every few records
        self._median_stale = True
        self._median_records = 0      # records at the last refresh
        self.records = 0              # record() calls (one per batch)
        self.items_seen = 0
        self.slow_batches = 0         # batches routed to the slow lane

    # ---- recording ---------------------------------------------------------
    def _slots(self, indices) -> np.ndarray:
        idx = np.asarray(indices, dtype=np.intp).reshape(-1)
        return idx // self.bucket

    def record(self, indices, total_seconds: float) -> None:
        """Attribute one batch's wall time over its items and fold into the
        EWMAs.  Batches only measure an aggregate, so attribution is the EM
        step that separates the estimates:

        * an *outlier* batch (total ≥ ``outlier_mult`` × B × the median
          item cost) is blamed proportionally to current predictions —
          excess lands on the member the tracker already believes is slow;
        * an evidently-*fast* batch is strong evidence every member is
          cheap: equal shares, folded in with the faster ``alpha_down``,
          so an item falsely blamed earlier (it shared a batch with a
          straggler while the tracker was cold) is exonerated within a
          couple of sightings instead of staying sticky-slow forever.
        """
        slots = self._slots(indices)
        if slots.size == 0 or total_seconds < 0:
            return
        with self._lock:
            self._maybe_refresh_median_locked()
            med = self._median
            if med > 0 and total_seconds < \
                    self.outlier_mult * slots.size * med:
                share = np.full(slots.size, total_seconds / slots.size)
                a = self.alpha_down
            else:
                est = self._ewma[slots]
                default = self._mean if self._mean > 0 \
                    else total_seconds / slots.size
                est = np.where(np.isnan(est), default, est)
                total_est = float(est.sum())
                if total_est <= 0:
                    share = np.full(slots.size, total_seconds / slots.size)
                else:
                    share = est * (total_seconds / total_est)
                a = self.alpha
            prev = self._ewma[slots]
            updated = np.where(np.isnan(prev), share,
                               (1 - a) * prev + a * share)
            self._ewma[slots] = updated
            batch_mean = total_seconds / slots.size
            self._mean = batch_mean if self.records == 0 \
                else (1 - self.alpha) * self._mean + self.alpha * batch_mean
            self.records += 1
            self.items_seen += int(slots.size)
            self._median_stale = True

    # ---- prediction --------------------------------------------------------
    def _refresh_median_locked(self) -> None:
        seen = self._ewma[~np.isnan(self._ewma)]
        self._median = float(np.median(seen)) if seen.size else 0.0
        self._median_stale = False
        self._median_records = self.records

    def _maybe_refresh_median_locked(self) -> None:
        """Throttled refresh: the O(slots) median scan runs at most once
        per 8 records (callers run per batch on the hot path)."""
        if self._median_stale and (self._median <= 0
                                   or self.records - self._median_records
                                   >= 8):
            self._refresh_median_locked()

    def predict(self, indices) -> np.ndarray:
        """Estimated per-item cost (the running mean for unseen items)."""
        with self._lock:
            est = self._ewma[self._slots(indices)]
            return np.where(np.isnan(est), self._mean, est)

    def is_slow(self, indices) -> bool:
        """Is any item of this batch predicted slow?  False while cold."""
        with self._lock:
            if self.records < self.min_records:
                return False
            self._maybe_refresh_median_locked()
            if self._median <= 0:
                return False
            est = self._ewma[self._slots(indices)]
            cut = self.threshold * self._median
            return bool(np.any(est[~np.isnan(est)] >= cut))

    def note_slow_batch(self) -> None:
        """Called by a pool when a batch is dispatched to the slow lane."""
        with self._lock:
            self.slow_batches += 1

    def forget(self, indices) -> None:
        """Drop the estimates for quarantined items (DESIGN.md §10): an
        id that exited service must stop dragging the median/tail stats.
        With ``bucket > 1`` the whole shared bucket resets — its surviving
        neighbours re-learn within a couple of sightings."""
        slots = self._slots(indices)
        if slots.size == 0:
            return
        with self._lock:
            self._ewma[slots[slots < self._ewma.size]] = np.nan
            self._median_stale = True

    # ---- tail statistics (io_counters / GoodputMonitor feed) ---------------
    def mean(self) -> float:
        return self._mean

    def quantile(self, q: float) -> float:
        with self._lock:
            seen = self._ewma[~np.isnan(self._ewma)]
            return float(np.quantile(seen, q)) if seen.size else 0.0

    def p99(self) -> float:
        return self.quantile(0.99)

    def tail_ratio(self) -> float:
        """p99 over median of the estimated per-item costs: ~1 on a uniform
        workload, large under a heavy tail — the retune-trigger signal."""
        with self._lock:
            self._refresh_median_locked()
            med = self._median
        return self.p99() / med if med > 0 else 0.0

    # ---- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        with self._lock:
            seen = ~np.isnan(self._ewma)
            return {
                "num_items": self.num_items,
                "bucket": self.bucket,
                "alpha": self.alpha,
                "threshold": self.threshold,
                "mean": self._mean,
                "records": self.records,
                "items_seen": self.items_seen,
                "slow_batches": self.slow_batches,
                # sparse: most datasets only ever touch a fraction of slots
                "slots": np.flatnonzero(seen).tolist(),
                "values": self._ewma[seen].tolist(),
            }

    def load_state_dict(self, d: dict) -> None:
        with self._lock:
            self.bucket = max(1, int(d.get("bucket", self.bucket)))
            n_slots = -(-self.num_items // self.bucket)
            self._ewma = np.full(n_slots, np.nan, dtype=np.float64)
            slots = np.asarray(d.get("slots", []), dtype=np.intp)
            vals = np.asarray(d.get("values", []), dtype=np.float64)
            keep = slots < n_slots
            self._ewma[slots[keep]] = vals[keep]
            self.alpha = float(d.get("alpha", self.alpha))
            self.threshold = float(d.get("threshold", self.threshold))
            self._mean = float(d.get("mean", 0.0))
            self.records = int(d.get("records", 0))
            self.items_seen = int(d.get("items_seen", 0))
            self.slow_batches = int(d.get("slow_batches", 0))
            self._median_stale = True

    # the lock is the only unpicklable member; process pools ship the
    # tracker to forked workers, so drop it and remint on arrival
    def __getstate__(self):
        with self._lock:
            state = self.__dict__.copy()
            state["_ewma"] = self._ewma.copy()
        state["_lock"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()


class KeyedCostTracker:
    """EWMA cost per hashable key (the serving frontend's request shapes).

    Same slow test as :class:`SampleCostTracker` — a key is slow when its
    estimate is at least ``threshold`` times the median over known keys —
    but the table is a dict, because request shapes are few and arbitrary.
    """

    def __init__(self, *, alpha: float = 0.3, threshold: float = 4.0,
                 min_records: int = 4):
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.min_records = int(min_records)
        self._ewma: Dict[Hashable, float] = {}
        self._lock = threading.Lock()
        self.records = 0

    def record(self, key: Hashable, seconds: float) -> None:
        if seconds < 0:
            return
        with self._lock:
            prev = self._ewma.get(key)
            self._ewma[key] = seconds if prev is None \
                else (1 - self.alpha) * prev + self.alpha * seconds
            self.records += 1

    def predict(self, key: Hashable) -> Optional[float]:
        with self._lock:
            return self._ewma.get(key)

    def is_slow(self, key: Hashable) -> bool:
        with self._lock:
            if self.records < self.min_records or len(self._ewma) < 2:
                return False
            est = self._ewma.get(key)
            if est is None:
                return False
            # median of the OTHER keys: serving mixes often have only a
            # couple of shapes, and a self-inclusive median would let one
            # expensive shape drag the reference up past its own cut
            others = [v for k, v in self._ewma.items() if k != key]
            med = float(np.median(others))
            return med > 0 and est >= self.threshold * med

    def __getstate__(self):
        with self._lock:
            state = self.__dict__.copy()
            state["_ewma"] = dict(self._ewma)
        state["_lock"] = None
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def state_dict(self) -> dict:
        with self._lock:
            return {"alpha": self.alpha, "threshold": self.threshold,
                    "records": self.records,
                    "keys": [list(k) if isinstance(k, tuple) else k
                             for k in self._ewma],
                    "values": list(self._ewma.values())}

    def load_state_dict(self, d: dict) -> None:
        with self._lock:
            self.alpha = float(d.get("alpha", self.alpha))
            self.threshold = float(d.get("threshold", self.threshold))
            self.records = int(d.get("records", 0))
            self._ewma = {
                (tuple(k) if isinstance(k, list) else k): float(v)
                for k, v in zip(d.get("keys", []), d.get("values", []))}


def percentile(samples: Sequence[float], q: float) -> float:
    """Small helper for latency reservoirs (serving p99)."""
    arr: List[float] = [float(s) for s in samples]
    if not arr:
        return 0.0
    return float(np.quantile(np.asarray(arr), q))
