"""Dataset = Storage + transform + collate.

Mirrors the four-step dataloader pipeline from the paper §2.1: (1) load from
storage, (2) transform to model-ready form, (3) shuffle/batch (sampler), (4)
prefetch (worker pool / device prefetcher).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.data.storage import ArrayStorage, Storage, StorageProfile
from repro.utils.fingerprint import dataset_fingerprint


class Dataset:
    def __init__(self, storage: Storage, transform: Optional[Callable] = None,
                 collate: Optional[Callable] = None):
        self.storage = storage
        self.transform = transform or (lambda x: x)
        self.collate = collate or default_collate

    def __len__(self):
        return len(self.storage)

    def get(self, idx: int):
        return self.transform(self.storage.read(idx))

    def get_batch(self, indices) -> Dict[str, np.ndarray]:
        return self.collate([self.get(i) for i in indices])

    def fingerprint(self) -> str:
        p = self.storage.profile()
        return dataset_fingerprint(item_bytes=p.item_bytes,
                                   decode_cost=p.decode_cpu_s_per_byte,
                                   num_items=p.num_items,
                                   item_bytes_std=p.item_bytes_std)


def default_collate(samples):
    """Stack a list of dict-or-array samples into batched arrays."""
    if isinstance(samples[0], dict):
        return {k: np.stack([s[k] for s in samples]) for k in samples[0]}
    return {"x": np.stack(samples)}


def image_transform(sample: np.ndarray, *, normalize: bool = True,
                    extra_flops: int = 0) -> Dict[str, np.ndarray]:
    """Decode-ish transform: cast, normalize, optional extra CPU work knob."""
    x = np.asarray(sample, dtype=np.float32)
    if normalize:
        x = x / 255.0 - 0.5
    for _ in range(extra_flops):
        x = x * 1.0000001  # tunable CPU burn for tests
    return {"image": x, "label": np.int32(0)}


def synthetic_image_dataset(num_items: int, resolution: int,
                            seed: int = 0) -> Dataset:
    """In-memory uint8 image dataset (CIFAR/COCO stand-in for tests)."""
    rng = np.random.default_rng(seed)
    items = [rng.integers(0, 255, (resolution, resolution, 3),
                          dtype=np.uint8) for _ in range(num_items)]
    return Dataset(ArrayStorage(items), transform=image_transform)


def token_dataset(num_items: int, seq_len: int, vocab: int,
                  seed: int = 0) -> Dataset:
    """Pre-tokenized LM dataset: items are (seq_len+1,) int32 sequences."""
    rng = np.random.default_rng(seed)
    items = [rng.integers(0, vocab, (seq_len + 1,)).astype(np.int32)
             for _ in range(num_items)]

    def transform(arr):
        return {"tokens": arr[:-1], "targets": arr[1:],
                "loss_mask": np.ones(seq_len, np.float32)}

    return Dataset(ArrayStorage(items), transform=transform)
