"""Dataset = Storage + transform + collate.

Mirrors the four-step dataloader pipeline from the paper §2.1: (1) load from
storage, (2) transform to model-ready form, (3) shuffle/batch (sampler), (4)
prefetch (worker pool / device prefetcher).

Two collation paths (DESIGN.md §3):

* **per-sample (legacy)** — B ``storage.read`` calls, B Python transform
  calls, ``np.stack`` over B tiny arrays per field;
* **batched fast path** — one ``storage.read_batch`` gather + one vectorized
  transform over the stacked ``(B, ...)`` raw block, optionally writing
  straight into a preallocated slab (``out=``) so nothing is allocated.

The fast path engages when the transform advertises a vectorized variant:
either pass ``batch_transform=`` explicitly, or set ``fn.batch_aware = True``
and ``fn.batch_variant = <vectorized fn>`` on the per-sample transform
(``image_transform`` and the token transform ship both).  Anything else —
ragged items, a plain transform, a transform swapped in after construction —
falls back to the per-sample path with identical results.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.data.storage import ArrayStorage, Storage, StorageProfile
from repro.utils.fingerprint import dataset_fingerprint


class Dataset:
    def __init__(self, storage: Storage, transform: Optional[Callable] = None,
                 collate: Optional[Callable] = None,
                 batch_transform: Optional[Callable] = None):
        self.storage = storage
        self.transform = transform or (lambda x: x)
        self.collate = collate or default_collate
        self._batch_transform = batch_transform

    def __len__(self):
        return len(self.storage)

    @property
    def batch_transform(self) -> Optional[Callable]:
        """The vectorized transform, if any — explicit ``batch_transform=``
        wins, else the live ``transform``'s advertised ``batch_variant``
        (looked up per call so swapping ``transform`` disables it too)."""
        if self._batch_transform is not None:
            return self._batch_transform
        if getattr(self.transform, "batch_aware", False):
            return getattr(self.transform, "batch_variant", None)
        return None

    @property
    def supports_fast_path(self) -> bool:
        return self.batch_transform is not None

    def get(self, idx: int):
        return self.transform(self.storage.read(idx))

    def get_batch(self, indices, *, out: Optional[Dict] = None,
                  fast: bool = True) -> Dict[str, np.ndarray]:
        """Collate the batch at ``indices``.

        ``fast=True`` (default) uses the batched read + vectorized transform
        when available; ``out`` is a dict of preallocated per-field arrays
        (an arena slot) to collate into — ignored (fresh arrays returned) if
        its batch dimension doesn't match ``len(indices)``.
        """
        bt = self.batch_transform if fast else None
        if bt is not None:
            raw = self.storage.read_batch(indices)
            stacked = raw if isinstance(raw, np.ndarray) else _try_stack(raw)
            if stacked is not None:
                if out is not None and not _out_fits(out, len(indices)):
                    out = None
                return bt(stacked, out=out)
            # ragged items: collate per-sample from the raw batch already in
            # hand (storage was charged once — don't read it again)
            return self.collate([self.transform(r) for r in raw])
        return self.collate([self.get(int(i)) for i in indices])

    def with_storage(self, storage: Storage) -> "Dataset":
        """Same transform/collate pipeline over a different storage — how
        the loader derives its cache-tier read view (``CachedStorage``)
        without copying transform wiring."""
        return Dataset(storage, transform=self.transform,
                       collate=self.collate,
                       batch_transform=self._batch_transform)

    def fingerprint(self) -> str:
        p = self.storage.profile()
        return dataset_fingerprint(item_bytes=p.item_bytes,
                                   decode_cost=p.decode_cpu_s_per_byte,
                                   num_items=p.num_items,
                                   item_bytes_std=p.item_bytes_std)


def _try_stack(items) -> Optional[np.ndarray]:
    try:
        return np.stack(items)
    except ValueError:      # ragged items -> per-sample fallback
        return None


def _out_fits(out: Dict[str, np.ndarray], batch: int) -> bool:
    return all(np.asarray(v).ndim >= 1 and np.asarray(v).shape[0] == batch
               for v in out.values())


def out_matches(out: Optional[Dict], spec: Dict[str, tuple]) -> bool:
    """Does ``out`` provide exactly the fields in ``spec`` ({name: (shape,
    dtype)})?  Batch transforms use this to reject a stale slab (e.g. the
    dataset was swapped under a persistent arena) instead of broadcasting
    into it or crashing."""
    if out is None:
        return False
    return set(out) == set(spec) and all(
        out[k].shape == shape and out[k].dtype == np.dtype(dtype)
        for k, (shape, dtype) in spec.items())


def default_collate(samples):
    """Stack a list of dict-or-array samples into batched arrays."""
    if isinstance(samples[0], dict):
        return {k: np.stack([s[k] for s in samples]) for k in samples[0]}
    return {"x": np.stack(samples)}


def image_transform(sample: np.ndarray, *, normalize: bool = True,
                    extra_flops: int = 0) -> Dict[str, np.ndarray]:
    """Decode-ish transform: cast, normalize, optional extra CPU work knob."""
    x = np.asarray(sample, dtype=np.float32)
    if normalize:
        x = x / 255.0 - 0.5
    for _ in range(extra_flops):
        x = x * 1.0000001  # tunable CPU burn for tests
    return {"image": x, "label": np.int32(0)}


def image_batch_transform(raw: np.ndarray, *, out: Optional[Dict] = None,
                          normalize: bool = True,
                          extra_flops: int = 0) -> Dict[str, np.ndarray]:
    """Vectorized ``image_transform`` over a stacked ``(B, ...)`` raw block.

    Byte-identical to per-sample: same cast, same ufunc chain, same dtypes —
    just one C call per op instead of B, and in-place into ``out`` slabs.
    """
    b = raw.shape[0]
    spec = {"image": (raw.shape, np.float32), "label": ((b,), np.int32)}
    if not out_matches(out, spec):
        out = {k: np.empty(shape, dtype) for k, (shape, dtype) in spec.items()}
    img = out["image"]
    img[...] = raw                       # uint8 -> float32 cast
    if normalize:
        np.divide(img, 255.0, out=img)
        np.subtract(img, 0.5, out=img)
    for _ in range(extra_flops):
        np.multiply(img, 1.0000001, out=img)
    out["label"][...] = 0
    return out


image_transform.batch_aware = True
image_transform.batch_variant = image_batch_transform


def synthetic_image_dataset(num_items: int, resolution: int,
                            seed: int = 0) -> Dataset:
    """In-memory uint8 image dataset (CIFAR/COCO stand-in for tests)."""
    rng = np.random.default_rng(seed)
    items = [rng.integers(0, 255, (resolution, resolution, 3),
                          dtype=np.uint8) for _ in range(num_items)]
    return Dataset(ArrayStorage(items), transform=image_transform)


def token_dataset(num_items: int, seq_len: int, vocab: int,
                  seed: int = 0) -> Dataset:
    """Pre-tokenized LM dataset: items are (seq_len+1,) int32 sequences."""
    rng = np.random.default_rng(seed)
    items = [rng.integers(0, vocab, (seq_len + 1,)).astype(np.int32)
             for _ in range(num_items)]

    def transform(arr):
        return {"tokens": arr[:-1], "targets": arr[1:],
                "loss_mask": np.ones(seq_len, np.float32)}

    def batch_transform(raw, *, out=None):
        b = raw.shape[0]
        spec = {"tokens": ((b, seq_len), np.int32),
                "targets": ((b, seq_len), np.int32),
                "loss_mask": ((b, seq_len), np.float32)}
        if not out_matches(out, spec):
            out = {k: np.empty(shape, dtype)
                   for k, (shape, dtype) in spec.items()}
        out["tokens"][...] = raw[:, :-1]
        out["targets"][...] = raw[:, 1:]
        out["loss_mask"][...] = 1.0
        return out

    transform.batch_aware = True
    transform.batch_variant = batch_transform
    return Dataset(ArrayStorage(items), transform=transform)
